"""RabbitCT quality benchmark: PSNR vs the analytic phantom.

RabbitCT scores accuracy against a reference volume; we hold the exact
voxelised phantom.  Checks the paper's claim that the fast paths (incl.
the reciprocal trick) keep reconstruction quality: every strategy and
the Pallas kernel must land within 0.05 dB of the scalar oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import quality_report, reconstruct
from repro.core.backproject import STRATEGIES
from repro.kernels.backproject_ops import pallas_backproject_one

from .common import bench_size, ct_problem, emit, STRATEGY_OPTS


def run(L: int | None = None, n_proj: int | None = None):
    L = bench_size(48, 16) if L is None else L
    n_proj = bench_size(64, 8) if n_proj is None else n_proj
    geom, filt, mats, ref = ct_problem(L, n_proj=n_proj)
    base_psnr = None
    for strat in STRATEGIES:
        vol = reconstruct(filt, mats, geom, strategy=strat,
                          **STRATEGY_OPTS[strat])
        q = quality_report(vol, ref)
        if strat == "scalar":
            base_psnr = q["psnr_roi_db"]
        emit(f"quality/{strat}", 0.0,
             f"psnr_roi_db={q['psnr_roi_db']:.3f} "
             f"delta_vs_scalar={q['psnr_roi_db'] - base_psnr:+.4f}")

    vol = jnp.zeros((L,) * 3, jnp.float32)
    for k in range(len(mats)):
        vol = pallas_backproject_one(vol, jnp.asarray(filt[k]),
                                     mats[k], geom, ty=8, chunk=24,
                                     band=16, width=128)
    q = quality_report(vol, ref)
    emit("quality/pallas", 0.0,
         f"psnr_roi_db={q['psnr_roi_db']:.3f} "
         f"delta_vs_scalar={q['psnr_roi_db'] - base_psnr:+.4f}")


if __name__ == "__main__":
    run()
