"""Paper Table 3 analogue: op-count efficiency vs runtime efficiency,
plus the section-5 clipping-mask improvement (~10% fewer voxels).

* "Instruction count efficiency" -> scalar-census total / strategy-census
  total (per voxel; >100% impossible, mirrors the paper's metric).
* "SIMD runtime efficiency" -> measured speedup over the scalar strategy
  on this backend divided by the notional lane advantage (the paper
  divides by SIMD width; our strategies share the backend vector width,
  so we report plain speedup as the runtime column).
* Clipping: exact per-line mask vs pre-fix conservative mask, voxels
  processed — the paper reports ~10% reduction at 512^3; the geometry
  ratio is resolution-dependent, we print both counts and the ratio.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_module import analyze_module
from repro.core.backproject import STRATEGIES, backproject_one
from repro.core.clipping import line_clip_conservative, line_clip_exact

from .common import bench_size, ct_problem, emit, time_fn, STRATEGY_OPTS


def run(L: int | None = None):
    L = bench_size(64, 16) if L is None else L
    geom, filt, mats, _ = ct_problem(L)
    vol0 = jnp.zeros((L,) * 3, jnp.float32)
    image = jnp.asarray(filt[0])
    A = jnp.asarray(mats[0])

    times = {}
    census_total = {}
    for strat in STRATEGIES:
        opts = STRATEGY_OPTS[strat]
        t = time_fn(backproject_one, vol0, image, A, geom,
                    strategy=strat, warmup=1, iters=3, **opts)
        times[strat] = t
        txt = jax.jit(
            lambda v, i, a, s=strat, o=opts: backproject_one(
                v, i, a, geom, strategy=s, **o)
        ).lower(vol0, image, A).compile().as_text()
        census_total[strat] = analyze_module(txt)["census"].get("total", 1)

    base_t = times["scalar"]
    base_c = census_total["scalar"]
    gups = {s: L ** 3 / t / 1e9 for s, t in times.items()}
    for strat in STRATEGIES:
        emit(f"table3/{strat}", times[strat] * 1e6,
             f"gups={gups[strat]:.4f} speedup={base_t / times[strat]:.2f} "
             f"op_count_eff={base_c / census_total[strat]:.2f} "
             f"ops={census_total[strat]}")

    # Clipping-mask improvement, averaged over projections.
    tot_exact = tot_cons = 0
    for k in range(len(mats)):
        Ak = np.asarray(mats[k], np.float64)
        tot_exact += line_clip_exact(geom, Ak).voxels
        tot_cons += line_clip_conservative(geom, Ak).voxels
    saved = 1.0 - tot_exact / max(tot_cons, 1)
    emit("table3/clipping", 0.0,
         f"exact_voxels={tot_exact} conservative_voxels={tot_cons} "
         f"saved_frac={saved:.3f}")


if __name__ == "__main__":
    run()
