"""Serving-tier latency vs offered load (beyond-paper figure).

The multi-tenant story (DESIGN.md §14) is a *curve*, not a throughput
number: what a clinic buying reconstruction-as-a-service feels is the
TTFV and completion-latency distribution at the load it offers, and how
both degrade as the tier saturates.  This module is the Poisson load
generator for that curve: scan arrivals are exponential with rate
``lambda = rho * capacity`` (capacity calibrated as ``n_slots / measured
single-scan service time``), every client streams its chunks through
:class:`repro.api.CTFrontDoor` and retries on :class:`Backpressure`
after the hinted delay.

Rows (one pair per offered load ``rho``):

* ``fig5/serve/rho{RRR}`` — ``us_per_call`` is the **p50 scan-completion
  latency** (intended arrival -> volume ready, backpressure retries
  included); the p99 and mean ride in the derived fields.
* ``fig5/ttfv/rho{RRR}`` — p50 time-to-first-volume (first chunk
  submitted -> volume ready).

The gate compares ``us_per_call`` only, so it gates the p50s — stable
medians — while the tail (p99) is recorded in every BENCH_ct.json entry
for the trajectory without putting a 99th percentile behind a 2.5x CI
noise gate.  Full scale runs thousands of scans; ``--tiny`` keeps the
same curve shape at CI size.
"""

from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.api import Backpressure, CTFrontDoor, Geometry, ProjectionChunk
from repro.core.phantom import make_dataset

from .common import bench_size, emit, record_extra

# Offered load as a fraction of calibrated capacity: comfortable,
# near-saturation, and overloaded (the regime where backpressure and
# policy choice, not kernel speed, set the latency).
RHOS = (0.3, 0.7, 1.2)


async def _client(fd, *, t0, arrival, projs, mats, chunk, n_proj, out):
    """One tenant: arrive at ``arrival``, retry through backpressure,
    stream the scan, await the volume, record latencies."""
    now = time.perf_counter() - t0
    if arrival > now:
        await asyncio.sleep(arrival - now)
    rejections = 0
    while True:
        try:
            ticket = await fd.open_scan(n_proj=n_proj)
            break
        except Backpressure as bp:
            rejections += 1
            await asyncio.sleep(bp.retry_after)
    first_submit = time.perf_counter()
    for c0 in range(0, n_proj, chunk):
        hi = min(c0 + chunk, n_proj)
        await fd.submit(ticket, ProjectionChunk(
            projs[c0:hi], mats[c0:hi], np.arange(c0, hi)))
    vol = await fd.result(ticket)
    np.asarray(vol)                       # block until the volume is real
    done = time.perf_counter()
    out.append({
        "arrival_s": arrival,
        "completion_s": done - (t0 + arrival),
        "ttfv_s": done - first_submit,
        "rejections": rejections,
    })


async def _run_load(geom, projs, mats, *, n_scans, chunk, lam, n_slots,
                    max_pending, pbatch, seed=0):
    fd = CTFrontDoor(geom, n_slots=n_slots, max_pending=max_pending,
                     policy="fifo", pbatch=pbatch)
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / lam, size=n_scans))
    out = []
    t0 = time.perf_counter()
    await asyncio.gather(*(
        _client(fd, t0=t0, arrival=float(a), projs=projs, mats=mats,
                chunk=chunk, n_proj=geom.n_proj, out=out)
        for a in arrivals))
    return out, fd.stats


def _calibrate(geom, projs, mats, *, chunk, n_slots, max_pending, pbatch):
    """Measured seconds per scan, after a compile-warming run."""

    async def once():
        fd = CTFrontDoor(geom, n_slots=n_slots, max_pending=max_pending,
                         policy="fifo", pbatch=pbatch)
        ticket = await fd.open_scan()
        t0 = time.perf_counter()
        for c0 in range(0, geom.n_proj, chunk):
            hi = min(c0 + chunk, geom.n_proj)
            await fd.submit(ticket, ProjectionChunk(
                projs[c0:hi], mats[c0:hi], np.arange(c0, hi)))
        np.asarray(await fd.result(ticket))
        return time.perf_counter() - t0

    asyncio.run(once())                   # warm the filter/fold traces
    return asyncio.run(once())


def run(L: int | None = None):
    L = bench_size(16, 10) if L is None else L
    n_proj = bench_size(16, 8)
    chunk = bench_size(4, 4)
    n_scans = bench_size(1000, 20)
    n_slots = 2
    max_pending = 2 * n_slots
    pbatch = 4
    geom = Geometry().scaled(L, n_proj=n_proj)
    projs, mats, _ = make_dataset(geom)
    projs = np.asarray(projs, np.float32)

    svc = _calibrate(geom, projs, mats, chunk=chunk, n_slots=n_slots,
                     max_pending=max_pending, pbatch=pbatch)
    capacity = n_slots / svc              # scans/s the slots can serve

    curve = []
    for rho in RHOS:
        lam = rho * capacity
        lat, stats = asyncio.run(_run_load(
            geom, projs, mats, n_scans=n_scans, chunk=chunk, lam=lam,
            n_slots=n_slots, max_pending=max_pending, pbatch=pbatch,
            seed=int(rho * 100)))
        comp = np.array([r["completion_s"] for r in lat])
        ttfv = np.array([r["ttfv_s"] for r in lat])
        rejected = int(sum(r["rejections"] for r in lat))
        tag = f"rho{int(round(rho * 100)):03d}"
        emit(f"fig5/serve/{tag}", float(np.percentile(comp, 50)) * 1e6,
             f"p99={np.percentile(comp, 99) * 1e6:.0f} "
             f"mean={comp.mean() * 1e6:.0f} lam={lam:.2f} "
             f"scans={n_scans} rejected={rejected} L={L} nproj={n_proj}")
        emit(f"fig5/ttfv/{tag}", float(np.percentile(ttfv, 50)) * 1e6,
             f"p99={np.percentile(ttfv, 99) * 1e6:.0f} rho={rho}")
        curve.append({
            "rho": rho, "lambda_scans_per_s": lam,
            "completion_p50_us": float(np.percentile(comp, 50)) * 1e6,
            "completion_p99_us": float(np.percentile(comp, 99)) * 1e6,
            "ttfv_p50_us": float(np.percentile(ttfv, 50)) * 1e6,
            "ttfv_p99_us": float(np.percentile(ttfv, 99)) * 1e6,
            "rejections": rejected, "stats": stats,
        })

    record_extra("fig5_serving", {
        "L": L, "n_proj": n_proj, "chunk": chunk, "n_scans": n_scans,
        "n_slots": n_slots, "max_pending": max_pending, "pbatch": pbatch,
        "service_s_per_scan": svc, "capacity_scans_per_s": capacity,
        "curve": curve})


if __name__ == "__main__":
    run()
