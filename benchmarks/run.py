"""Benchmark harness: one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV lines.  Mapping to the paper:

==========================  ==============================================
module                      paper artifact
==========================  ==============================================
table2_op_census            Table 2 (instruction count/composition/part)
table3_efficiency           Table 3 (+ section-5 clipping-mask claim)
table4_gather_micro         Table 4 (gather latency vs distribution)
table5_traffic              beyond-paper: volume-HBM-traffic model vs time
fig1_single_device          Fig. 1 (single-core strategy comparison)
fig2_scaling                Fig. 2 (full-system scaling)
fig3_codegen                Fig. 3 (compiler vs hand-structured)
fig4_streaming              beyond-paper: streamed-engine time-to-first-
                            volume + projections/s at B concurrent scans
fig5_serving                beyond-paper: serving-tier TTFV + p50/p99
                            completion latency vs Poisson offered load
dispatch                    beyond-paper: auto-dispatch resolution cost
                            (cold in-situ selection vs warm cache hit)
cycle_model                 Section 6.4 (per-iteration cycle breakdown)
quality                     RabbitCT accuracy score (PSNR)
lm_gather                   the technique on the assigned LM archs
==========================  ==============================================

``python -m benchmarks.run [--only name[,name...]] [--json PATH] [--tiny]``

``--json PATH`` appends one machine-readable run entry (device meta,
every emitted row with its parsed ``key=value`` fields, and structured
extras such as the autotuner's chosen config) to ``PATH`` — the perf
trajectory file (``BENCH_ct.json``) every future PR extends.  ``--tiny``
shrinks the standard problems to CI-sized shapes.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from . import common
from . import (ct_hillclimb, cycle_model, dispatch, fig1_single_device,
               fig2_scaling, fig3_codegen, fig4_streaming, fig5_serving,
               lm_gather, moe_dispatch, quality, table2_op_census,
               table3_efficiency, table4_gather_micro, table5_traffic)

MODULES = [
    ("table2_op_census", table2_op_census),
    ("table3_efficiency", table3_efficiency),
    ("table4_gather_micro", table4_gather_micro),
    ("fig1_single_device", fig1_single_device),
    ("table5_traffic", table5_traffic),
    ("fig2_scaling", fig2_scaling),
    ("fig3_codegen", fig3_codegen),
    ("fig4_streaming", fig4_streaming),
    ("fig5_serving", fig5_serving),
    ("dispatch", dispatch),
    ("cycle_model", cycle_model),
    ("quality", quality),
    ("lm_gather", lm_gather),
    ("ct_hillclimb", ct_hillclimb),
    ("moe_dispatch", moe_dispatch),
]


def _write_json(path: str, ran: list[str], n_fail: int) -> None:
    """Append this run as one trajectory entry to ``path``."""
    from repro.tune import device_identity

    backend, device_kind = device_identity()
    entry = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "meta": {
            "backend": backend,
            "device_kind": device_kind,
            "jax_version": jax.__version__,
            "tiny": common.TINY,
            "modules": ran,
            "failures": n_fail,
        },
        "rows": common.RESULTS,
        "extras": common.EXTRAS,
    }
    p = Path(path)
    doc = {"runs": []}
    if p.is_file():
        try:
            old = json.loads(p.read_text())
            if isinstance(old, dict) and isinstance(old.get("runs"), list):
                doc = old
        except json.JSONDecodeError:
            pass                    # unreadable trajectory: start fresh
    doc["runs"].append(entry)
    p.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path} ({len(doc['runs'])} run(s))", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run selected modules (comma-separated names)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append a machine-readable run entry to PATH")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized problem shapes")
    args = ap.parse_args(argv)
    names = [n for n, _ in MODULES]
    only = None
    if args.only is not None:
        only = [n.strip() for n in args.only.split(",") if n.strip()]
        bad = [n for n in only if n not in names]
        if bad or not only:
            missing = ", ".join(repr(n) for n in (bad or [args.only]))
            print(f"unknown module {missing}; valid modules: "
                  f"{', '.join(names)}", file=sys.stderr)
            raise SystemExit(2)
    # Assign, don't latch: a prior in-process main(["--tiny"]) must not
    # leak tiny shapes into a later full-size run (RESULTS/EXTRAS were
    # already reset per invocation; TINY was not).
    common.TINY = bool(args.tiny) or common.TINY_ENV
    # Fresh collection state per invocation: a second in-process main()
    # (tests, notebooks) must not replay the previous run's rows/extras
    # into its --json trajectory entry.
    common.RESULTS.clear()
    common.EXTRAS.clear()
    print("name,us_per_call,derived")
    n_fail = 0
    ran = []
    for name, mod in MODULES:
        if only is not None and name not in only:
            continue
        ran.append(name)
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001 — keep the harness going
            n_fail += 1
            print(f"# {name} FAILED:")
            traceback.print_exc()
    if args.json:
        _write_json(args.json, ran, n_fail)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
