"""Benchmark harness: one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV lines.  Mapping to the paper:

==========================  ==============================================
module                      paper artifact
==========================  ==============================================
table2_op_census            Table 2 (instruction count/composition/part)
table3_efficiency           Table 3 (+ section-5 clipping-mask claim)
table4_gather_micro         Table 4 (gather latency vs distribution)
fig1_single_device          Fig. 1 (single-core strategy comparison)
fig2_scaling                Fig. 2 (full-system scaling)
fig3_codegen                Fig. 3 (compiler vs hand-structured)
cycle_model                 Section 6.4 (per-iteration cycle breakdown)
quality                     RabbitCT accuracy score (PSNR)
lm_gather                   the technique on the assigned LM archs
==========================  ==============================================

``python -m benchmarks.run [--only name]``
"""

from __future__ import annotations

import argparse
import time
import traceback

from . import (ct_hillclimb, cycle_model, fig1_single_device,
               fig2_scaling, fig3_codegen, lm_gather, moe_dispatch,
               quality, table2_op_census, table3_efficiency,
               table4_gather_micro)

MODULES = [
    ("table2_op_census", table2_op_census),
    ("table3_efficiency", table3_efficiency),
    ("table4_gather_micro", table4_gather_micro),
    ("fig1_single_device", fig1_single_device),
    ("fig2_scaling", fig2_scaling),
    ("fig3_codegen", fig3_codegen),
    ("cycle_model", cycle_model),
    ("quality", quality),
    ("lm_gather", lm_gather),
    ("ct_hillclimb", ct_hillclimb),
    ("moe_dispatch", moe_dispatch),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    n_fail = 0
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001 — keep the harness going
            n_fail += 1
            print(f"# {name} FAILED:")
            traceback.print_exc()
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
