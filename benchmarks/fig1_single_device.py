"""Paper Fig. 1 analogue: single-device back projection throughput.

GUP/s (billions of voxel updates per second) per gather strategy for one
projection on one device — the paper's single-core SIMD comparison.
(The SMT column of Fig. 1 has no single-device analogue here; latency
hiding is the Pallas grid pipeline, measured structurally in fig3.)

After the per-strategy rows, the autotuner sweeps its candidate space on
this geometry, persists the winner (``.repro_tune/``), and the
``fig1/auto`` row times ``strategy="auto"`` resolving through that cache
— the chosen config lands in the ``--json`` trajectory via
``record_extra``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.backproject import STRATEGIES, backproject_one
from repro.tune import autotune

from .common import (STRATEGY_OPTS, bench_size, ct_problem, emit,
                     record_extra, time_fn)


def run(L: int | None = None):
    L = bench_size(96, 16) if L is None else L
    geom, filt, mats, _ = ct_problem(L, n_proj=bench_size(4, 2))
    vol0 = jnp.zeros((L,) * 3, jnp.float32)
    image = jnp.asarray(filt[0])
    A = jnp.asarray(mats[0])
    for strat in STRATEGIES:
        t = time_fn(backproject_one, vol0, image, A, geom,
                    strategy=strat, warmup=1, iters=3,
                    **STRATEGY_OPTS[strat])
        emit(f"fig1/{strat}", t * 1e6,
             f"gups={L ** 3 / t / 1e9:.4f} L={L}")

    cfg = autotune(geom, image=image, A=A, warmup=1, iters=3)
    t = time_fn(backproject_one, vol0, image, A, geom,
                strategy=cfg.strategy, warmup=1, iters=3, **cfg.opts)
    emit("fig1/auto", t * 1e6,
         f"gups={L ** 3 / t / 1e9:.4f} L={L} chosen={cfg.strategy}")
    record_extra("tuned_config", cfg.as_dict())


if __name__ == "__main__":
    run()
