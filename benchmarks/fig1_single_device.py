"""Paper Fig. 1 analogue: single-device back projection throughput.

GUP/s (billions of voxel updates per second) per gather strategy for one
projection on one device — the paper's single-core SIMD comparison.
(The SMT column of Fig. 1 has no single-device analogue here; latency
hiding is the Pallas grid pipeline, measured structurally in fig3.)
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.backproject import STRATEGIES, backproject_one

from .common import ct_problem, emit, time_fn, STRATEGY_OPTS


def run(L: int = 96):
    geom, filt, mats, _ = ct_problem(L, n_proj=4)
    vol0 = jnp.zeros((L,) * 3, jnp.float32)
    image = jnp.asarray(filt[0])
    A = jnp.asarray(mats[0])
    for strat in STRATEGIES:
        t = time_fn(backproject_one, vol0, image, A, geom,
                    strategy=strat, warmup=1, iters=3,
                    **STRATEGY_OPTS[strat])
        emit(f"fig1/{strat}", t * 1e6,
             f"gups={L ** 3 / t / 1e9:.4f} L={L}")


if __name__ == "__main__":
    run()
