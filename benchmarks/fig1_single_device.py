"""Paper Fig. 1 analogue: single-device back projection throughput.

GUP/s (billions of voxel updates per second) per gather strategy for one
projection on one device — the paper's single-core SIMD comparison.
(The SMT column of Fig. 1 has no single-device analogue here; latency
hiding is the Pallas grid pipeline, measured structurally in fig3.)

After the per-strategy rows, ``fig1/batch/p*`` times the projection-
batched loop nest (DESIGN.md §7) against the per-projection nest at
several ``pbatch`` depths — same strategy, same projections, only the
volume-residency structure changes.  ``fig1/batch_db/p*`` and
``fig1/batch_micro/p*`` then time the batched *Pallas kernel* variants
(DESIGN.md §9: deep DMA pipeline, micro-window compute) on a smaller
kernel-sized volume — structural numbers in interpret mode off-TPU,
compiled on TPU, comparable within one backend either way.  Then the
autotuner sweeps its candidate space on this geometry (now including
the ``pbatch × {plain, db, micro}`` cross), persists the winner
(``.repro_tune/``), and the ``fig1/auto`` row times ``strategy="auto"``
resolving through that cache — the chosen config lands in the
``--json`` trajectory via ``record_extra``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.api import autotune, reconstruct
from repro.core.backproject import STRATEGIES, backproject_one
from repro.kernels.backproject_ops import pallas_backproject_batch

from .common import (STRATEGY_OPTS, bench_size, ct_problem, emit,
                     record_extra, time_fn)

PBATCHES = (1, 2, 4)
KERNEL_PBATCHES = (2, 4)


def run(L: int | None = None):
    L = bench_size(96, 16) if L is None else L
    n_proj = bench_size(4, 2)
    geom, filt, mats, _ = ct_problem(L, n_proj=n_proj)
    vol0 = jnp.zeros((L,) * 3, jnp.float32)
    image = jnp.asarray(filt[0])
    A = jnp.asarray(mats[0])
    for strat in STRATEGIES:
        t = time_fn(backproject_one, vol0, image, A, geom,
                    strategy=strat, warmup=1, iters=3,
                    **STRATEGY_OPTS[strat])
        emit(f"fig1/{strat}", t * 1e6,
             f"gups={L ** 3 / t / 1e9:.4f} L={L}")

    # Batched vs per-projection: full n_proj reconstruction per call,
    # pbatch=1 is the classical nest.  gups counts every voxel update.
    # Depths clamp to n_proj (tiny mode) — emit the *effective* depth
    # once, never a duplicate measurement under an inflated label.
    for pb in sorted({min(pb, n_proj) for pb in PBATCHES}):
        t = time_fn(reconstruct, filt, mats, geom, strategy="strip2",
                    pbatch=pb, warmup=1, iters=2,
                    **STRATEGY_OPTS["strip2"])
        emit(f"fig1/batch/p{pb}", t * 1e6,
             f"gups={n_proj * L ** 3 / t / 1e9:.4f} L={L} pbatch={pb} "
             f"nproj={n_proj}")

    # bf16 on the wire at the default batch depth: identical tap
    # semantics at half the strip bytes (f32 accumulate; DESIGN.md §10).
    pb16 = min(4, n_proj)
    t = time_fn(reconstruct, filt, mats, geom, strategy="strip2",
                pbatch=pb16, strip_dtype="bfloat16", warmup=1, iters=2,
                **STRATEGY_OPTS["strip2"])
    emit("fig1/strip2_bf16", t * 1e6,
         f"gups={n_proj * L ** 3 / t / 1e9:.4f} L={L} pbatch={pb16} "
         f"nproj={n_proj}")

    # Batched kernel variants: full n_proj stack per call through the
    # Pallas batch path, db (depth-2 rotation) and micro-window compute.
    # A smaller volume keeps interpret-mode (off-TPU) rows tractable;
    # the rows compare variants against each other, not against the jnp
    # rows above.
    Lk = bench_size(32, 16)
    geom_k, filt_k, mats_k, _ = ct_problem(Lk, n_proj=n_proj)
    vol0_k = jnp.zeros((Lk,) * 3, jnp.float32)
    tiles = dict(ty=8, chunk=min(32, Lk), band=16, width=128)
    for pb in sorted({min(pb, n_proj) for pb in KERNEL_PBATCHES}):
        for tag, flags in (("batch_db", dict(double_buffer=True,
                                             db_depth=2)),
                           ("batch_micro", dict(micro=True)),
                           ("batch_shared", dict(shared_window=True)),
                           ("batch_shared_bf16",
                            dict(shared_window=True,
                                 strip_dtype="bfloat16"))):
            # A wider sampling window than the 50 ms default: these rows
            # feed the tightened regression gate, and interpret-mode
            # medians over ~10 samples drift with host contention.
            t = time_fn(pallas_backproject_batch, vol0_k, filt_k, mats_k,
                        geom_k, pbatch=pb, warmup=1, iters=3,
                        min_total_s=0.3, **tiles, **flags)
            emit(f"fig1/{tag}/p{pb}", t * 1e6,
                 f"gups={n_proj * Lk ** 3 / t / 1e9:.4f} L={Lk} "
                 f"pbatch={pb} nproj={n_proj}")

    cfg = autotune(geom, image=image, A=A, warmup=1, iters=3)
    opts = dict(cfg.opts)
    pbatch = int(opts.pop("pbatch", 1))
    if pbatch == 1:
        t = time_fn(backproject_one, vol0, image, A, geom,
                    strategy=cfg.strategy, warmup=1, iters=3, **opts)
    else:
        # Same problem construction as the sweep that picked this
        # config: distinct matrices, so the strip-origin churn (and
        # therefore the cost) matches the number the tuner measured.
        from repro.core.backproject import backproject_batch
        from repro.tune.sweep import _batch_problem

        images, mats_b = _batch_problem(geom, image, pbatch)
        t = time_fn(backproject_batch, vol0, images, mats_b, geom,
                    strategy=cfg.strategy, pbatch=pbatch, warmup=1,
                    iters=3, **opts) / pbatch
    emit("fig1/auto", t * 1e6,
         f"gups={L ** 3 / t / 1e9:.4f} L={L} chosen={cfg.strategy} "
         f"pbatch={pbatch}")
    record_extra("tuned_config", cfg.as_dict())


if __name__ == "__main__":
    run()
