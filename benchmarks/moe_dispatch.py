"""MoE dispatch implementations: census + numerical agreement.

The reproducible small-scale evidence behind hillclimb LM-2: all four
dispatch implementations agree numerically (dropless regime), and the
op census shows what each lowering is made of (scatter/gather HLOs vs
pure einsums).  The 512-device collective comparison lives in
experiments/dryrun vs experiments/dryrun_opt; this runs anywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_module import analyze_module
from repro.configs.base import ModelConfig
from repro.models.layers import Param
from repro.models.moe import moe_forward, init_moe

from .common import emit, time_fn


def run(E: int = 8, k: int = 2, d: int = 64, ff: int = 32):
    cfg = ModelConfig(name="bench", family="moe", n_layers=2, d_model=d,
                      n_heads=4, n_kv_heads=2, d_ff=0, vocab=64,
                      moe=True, n_experts=E, top_k=k, moe_d_ff=ff,
                      capacity_factor=8.0, param_dtype="float32")
    p = Param(jax.random.PRNGKey(0), jnp.float32)
    init_moe(p, cfg)
    params = p.params
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, d), jnp.float32)

    ref, _ = moe_forward(params, cfg, x, impl="scatter",
                         dtype=jnp.float32)
    for impl in ("scatter", "einsum", "grouped"):
        fn = jax.jit(lambda pp, xx, i=impl: moe_forward(
            pp, cfg, xx, impl=i, dtype=jnp.float32)[0])
        t = time_fn(fn, params, x)
        out = fn(params, x)
        err = float(jnp.abs(out - ref).max())
        an = analyze_module(fn.lower(params, x).compile().as_text())
        emit(f"moe_dispatch/{impl}", t * 1e6,
             f"maxdiff={err:.1e} gather_ops="
             f"{an['census'].get('gather', 0)} flops={an['flops']:.2e}")
    # impl="ep" falls back to scatter without a mesh context: assert it.
    out_ep, _ = moe_forward(params, cfg, x, impl="ep", dtype=jnp.float32)
    emit("moe_dispatch/ep(no-mesh-fallback)", 0.0,
         f"maxdiff={float(jnp.abs(out_ep - ref).max()):.1e}")


if __name__ == "__main__":
    run()
