"""Paper Table 4 analogue: gather cost vs element distribution.

The paper microbenchmarks ``vgatherdps`` latency as a function of how
many of the 16 gathered elements share a cache line (16/8/4/2/1 per CL).
The TPU re-parameterisation: gather N elements whose indices fall ``d``
per 128-element tile row (the VMEM lane tile) — the fewer per row, the
more rows the gather emulation must touch.

Measured on this backend: XLA gather (``take``) vs one-hot MXU gather vs
strip block-load, same index distributions.  Derived column reports the
modeled TPU cost terms (bytes touched for take at tile granularity,
flops for onehot), which is what EXPERIMENTS.md §Perf quotes — the
measured CPU times validate the *ordering*, the model gives the TPU
numbers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gather_ops import onehot_gather, take_gather

from .common import bench_size, emit, time_fn

ROW = 128      # lane-tile width


def _indices(n: int, per_row: int, rows: int, seed=0) -> np.ndarray:
    """n indices spread so ``per_row`` land in each touched row."""
    rng = np.random.default_rng(seed)
    n_rows_touched = n // per_row
    row_ids = rng.permutation(rows)[:n_rows_touched]
    idx = []
    for r in row_ids:
        cols = rng.choice(ROW, size=per_row, replace=False)
        idx.extend(r * ROW + cols)
    return np.asarray(idx[:n], np.int32)


def _strip_gather(table, ids, per_row):
    """Block-load analogue: slice whole rows, select within."""
    rows = ids // ROW
    cols = ids % ROW
    urows = rows.reshape(-1, per_row)[:, 0]       # one slice per row
    blocks = jax.vmap(
        lambda r: jax.lax.dynamic_slice(table, (r * ROW,), (ROW,)))(urows)
    sel = jax.nn.one_hot(cols.reshape(-1, per_row), ROW,
                         dtype=table.dtype)
    return jnp.einsum("npk,nk->np", sel,
                      blocks).reshape(-1)


def run(n: int | None = None, rows: int | None = None):
    n = bench_size(4096, 512) if n is None else n
    rows = bench_size(512, 64) if rows is None else rows
    table1d = jnp.arange(rows * ROW, dtype=jnp.float32)
    table2d = table1d.reshape(rows * ROW, 1)

    take_j = jax.jit(lambda t, i: take_gather(t, i))
    onehot_j = jax.jit(lambda t, i: onehot_gather(t, i, chunk=2048))

    for per_row in (16, 8, 4, 2, 1):
        ids = jnp.asarray(_indices(n, per_row, rows))
        t_take = time_fn(take_j, table1d, ids)
        t_oh = time_fn(onehot_j, table2d, ids)
        strip_j = jax.jit(lambda t, i, p=per_row: _strip_gather(t, i, p))
        t_strip = time_fn(strip_j, table1d, ids)
        # TPU model: take touches ceil(n/per_row) tile-rows of 512B;
        # onehot does 2*n*V flops on the MXU.
        rows_touched = n // per_row
        model_bytes = rows_touched * ROW * 4
        model_flops = 2 * n * rows * ROW
        emit(f"table4/per_row={per_row}", t_take * 1e6,
             f"take_us={t_take * 1e6:.1f} onehot_us={t_oh * 1e6:.1f} "
             f"strip_us={t_strip * 1e6:.1f} "
             f"tpu_take_bytes={model_bytes} tpu_onehot_flops={model_flops}")


if __name__ == "__main__":
    run()
