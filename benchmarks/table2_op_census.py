"""Paper Table 2 analogue: op count & composition per algorithm part.

The paper counts x86 instructions per kernel part (memory / shuffle /
arithmetic) for each SIMD ISA.  Here we count optimised-HLO instructions
(loop-weighted) per class for each TPU gather strategy, for one plane
update.  The paper's qualitative findings to check against:

* Part 1 is cheap and identical across strategies (streaming math);
* Part 2 dominates and differs wildly: ``gather`` emits gather HLOs
  ("hardware gather"), ``onehot``/``strip`` emit zero gathers but pay in
  dot/select arithmetic (MXU as texture unit);
* zero-padding removes all per-tap conditionals (no select-on-bounds in
  the gather path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.hlo_module import analyze_module
from repro.core.backproject import (GeomStatic, STRATEGIES, _pad_image,
                                    _sample, accumulate, plane_coords)

from .common import bench_size, ct_problem, emit, STRATEGY_OPTS


def _census(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_module(txt)


def run(L: int | None = None):
    L = bench_size(64, 16) if L is None else L
    geom, filt, mats, _ = ct_problem(L)
    gs = GeomStatic.of(geom)
    image = jnp.asarray(filt[0])
    padded = _pad_image(image)
    A = jnp.asarray(mats[0])
    z = jnp.int32(L // 2)

    # Part 1 alone (identical for every strategy).
    a1 = _census(lambda A, z: plane_coords(A, gs, z), A, z)
    c = a1["census"]
    emit("table2/part1/all", 0.0,
         f"mem={c.get('memory', 0)} shuf={c.get('shuffle', 0)} "
         f"arith={c.get('arith', 0)} gather={c.get('gather', 0)} "
         f"total={c.get('total', 0)}")

    ix, iy, w = plane_coords(A, gs, z)
    plane = jnp.zeros((L, L), jnp.float32)

    for strat in STRATEGIES:
        opts = STRATEGY_OPTS[strat]

        def part2(image, padded, ix, iy):
            return _sample(strat, image, padded, ix, iy, gs, dict(opts))

        a2 = _census(part2, image, padded, ix, iy)
        c2 = a2["census"]
        gather_ops = c2.get("gather", 0)
        emit(f"table2/part2/{strat}", 0.0,
             f"mem={c2.get('memory', 0)} shuf={c2.get('shuffle', 0)} "
             f"arith={c2.get('arith', 0)} gather={gather_ops} "
             f"total={c2.get('total', 0)} flops={a2['flops']:.2e}")

    val = _sample("gather", image, padded, ix, iy, gs, {})
    a3 = _census(lambda p, v, w: accumulate(p, v, w), plane, val, w)
    c3 = a3["census"]
    emit("table2/part3/all", 0.0,
         f"mem={c3.get('memory', 0)} shuf={c3.get('shuffle', 0)} "
         f"arith={c3.get('arith', 0)} total={c3.get('total', 0)}")


if __name__ == "__main__":
    run()
