"""Paper section 6.4 analogue: per-voxel cost decomposition.

The paper decomposes one KNC kernel iteration into 107 cycles — 37.5
compute + 59.2 gather + 10 L2 — concluding gather = 65% of runtime.  The
TPU analogue decomposes the per-voxel cost of each strategy into the
three roofline terms from the *lowered HLO* of one plane update, scaled
to the full RabbitCT problem (512^3 x 496 projections, hardware
constants from repro.analysis.hlo), and reports which term dominates —
the dry-run-era equivalent of "69 of 107 cycles are gather".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.hlo import HBM_BW, PEAK_FLOPS
from repro.analysis.hlo_module import analyze_module
from repro.core.backproject import STRATEGIES, backproject_one

from .common import bench_size, ct_problem, emit, STRATEGY_OPTS

FULL_VOXELS = 512 ** 3 * 496       # medically relevant problem


def run(L: int | None = None):
    L = bench_size(64, 16) if L is None else L
    geom, filt, mats, _ = ct_problem(L)
    vol0 = jnp.zeros((L,) * 3, jnp.float32)
    image = jnp.asarray(filt[0])
    A = jnp.asarray(mats[0])
    voxels = L ** 3

    for strat in STRATEGIES:
        opts = STRATEGY_OPTS[strat]
        txt = jax.jit(
            lambda v, i, a, s=strat, o=opts: backproject_one(
                v, i, a, geom, strategy=s, **o)
        ).lower(vol0, image, A).compile().as_text()
        a = analyze_module(txt)
        fl_vox = a["flops"] / voxels
        by_vox = a["bytes"] / voxels
        t_compute = fl_vox / PEAK_FLOPS
        t_memory = by_vox / HBM_BW
        dom = "compute" if t_compute > t_memory else "memory"
        full_s = max(t_compute, t_memory) * FULL_VOXELS
        emit(f"cycle_model/{strat}", 0.0,
             f"flops_per_voxel={fl_vox:.0f} bytes_per_voxel={by_vox:.0f} "
             f"dominant={dom} full_rabbitct_s_1chip={full_s:.1f} "
             f"gups_1chip={FULL_VOXELS / full_s / 1e9:.2f}")


if __name__ == "__main__":
    run()
