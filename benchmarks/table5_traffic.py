"""Table 5 (beyond-paper): modelled HBM traffic vs measured time.

The back projection is memory-bound on its streaming part (the paper's
kernels sustain a handful of flops per voxel update; Treibig et al.,
arXiv:1104.5243, show throughput on real hardware is decided by the
memory-locality structure).  The loop-nest inversion of DESIGN.md §7
makes the dominant traffic terms explicit:

* **volume**: each projection batch streams the ``L³`` f32 volume
  through memory once (read + write) —
  ``2 · ceil(n_proj / pbatch) · L³ · 4`` bytes;
* **projections (strips)**: one window load per (projection, window
  unit), where the window unit is whatever the *executed* configuration
  says — ``(gband, gwidth)`` per ``group`` voxels for the jnp ``strip2``
  rows, ``(band, width)`` per ``(ty, chunk)`` tile for the kernel path,
  ``× 0.5`` when the wire dtype is bf16, ``× 0.25`` plus the
  once-per-projection scale sideband when it is int8
  (:func:`scale_sideband_bytes`), and a per-*group* superset window for
  the shared-window kernel.

An earlier revision hard-coded the kernel tile ``(8, 32, 16, 128)`` into
the strip term of every row while the timed rows ran the jnp ``strip2``
path — the committed model described a configuration nothing executed.
Every row below derives its strip bytes from the options it actually
runs (DESIGN.md §10); the one remaining kernel-tile model is its own
row, explicitly labelled modelled-not-timed (``us=0`` keeps it out of
the regression gate, whose row filter requires a positive timing).
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.backproject import (DEFAULT_PBATCH, GeomStatic,
                                    _divisor_at_most, reconstruct)
from repro.core.quality import psnr, roi_mask

from .common import bench_size, ct_problem, emit, record_extra, time_fn
from .fig1_single_device import PBATCHES

_ITEMSIZE = {"float32": 4, "bfloat16": 2, "int8": 1}


def scale_sideband_bytes(geom, n_proj: int) -> int:
    """Modelled int8-wire scale/offset sideband: 8 bytes (two f32) per
    padded detector row per projection, counted ONCE per projection —
    the ``(2, rows)`` scale block is fetched whole and stays VMEM- (or
    cache-) resident across every window of its projection (the Pallas
    wrappers pin it with a constant-index BlockSpec), unlike the strip
    windows, which are re-fetched per window unit.  Charging it per
    window would model a fetch pattern nothing executes.
    """
    return n_proj * (geom.n_v + 2) * 8


def volume_bytes(L: int, n_proj: int, pbatch: int) -> int:
    """Modelled volume HBM bytes per reconstruction (f32 read+write per
    volume pass; one pass per projection batch)."""
    return 2 * math.ceil(n_proj / pbatch) * L ** 3 * 4


def strip_bytes(geom, strategy: str, opts: dict,
                n_proj: int | None = None) -> int:
    """Modelled projection-side HBM bytes for the configuration a row
    actually executes (jnp strategies).

    ``strip``/``strip2`` load one ``(band, width)`` window per chunk /
    ``(gband, gwidth)`` per voxel group — window count and dims resolve
    exactly as the samplers resolve them (divisor-clamped chunk,
    geometry-clamped dims), at the wire itemsize.  The windowless
    strategies (``scalar``/``gather``/``onehot``) are modelled as their
    four scattered bilinear taps per voxel.  Independent of ``pbatch``
    — batching cuts only the volume term.  The int8 wire adds its
    per-projection scale sideband (:func:`scale_sideband_bytes`) on top
    of the 1-byte windows — codes + scales, nothing hidden.
    """
    L = geom.L
    n_proj = geom.n_proj if n_proj is None else n_proj
    dtype = str(opts.get("strip_dtype", "float32"))
    itemsize = _ITEMSIZE[dtype]
    sideband = scale_sideband_bytes(geom, n_proj) if dtype == "int8" else 0
    if strategy == "strip2":
        group = _divisor_at_most(L, int(opts.get("group", 8)))
        band = min(int(opts.get("gband", 8)), geom.n_v + 2)
        width = min(int(opts.get("gwidth", 64)), geom.n_u + 2)
        windows = L * L * (L // group)
    elif strategy == "strip":
        chunk = _divisor_at_most(L, int(opts.get("chunk", 128)))
        band = min(int(opts.get("band", 16)), geom.n_v + 2)
        width = min(int(opts.get("width", 512)), geom.n_u + 2)
        windows = L * L * (L // chunk)
    elif strategy in ("scalar", "gather", "onehot"):
        return n_proj * L ** 3 * 4 * itemsize + sideband
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return n_proj * windows * band * width * itemsize + sideband


def pallas_strip_bytes(geom, *, ty: int, chunk: int, band: int, width: int,
                       itemsize: int = 4, n_proj: int | None = None) -> int:
    """Modelled kernel-path strip HBM bytes: one ``(band, width)`` DMA
    per (projection, z, y-block, x-chunk) volume tile."""
    L = geom.L
    n_proj = geom.n_proj if n_proj is None else n_proj
    tiles = L * max(1, L // ty) * max(1, L // chunk)
    return n_proj * tiles * band * width * itemsize


def shared_window_traffic(geom, *, ty: int, chunk: int, band: int,
                          width: int, pbatch: int, itemsize: int,
                          n_proj: int | None = None) -> tuple[int, int]:
    """Modelled ``(bytes, dma_descriptors)`` for the shared-window
    kernel: one ``(group_size, band, width)`` slab DMA per (volume tile,
    projection group).  Bytes still scale with ``n_proj`` (each member's
    slab plane is distinct pixels); the ``pbatch``× win is in
    *descriptors* — and in bytes exactly when the superset dims beat
    ``pbatch`` separate per-projection windows."""
    L = geom.L
    n_proj = geom.n_proj if n_proj is None else n_proj
    tiles = L * max(1, L // ty) * max(1, L // chunk)
    groups = math.ceil(n_proj / pbatch)
    return tiles * n_proj * band * width * itemsize, tiles * groups


def run(L: int | None = None, n_proj: int | None = None):
    L = bench_size(64, 16) if L is None else L
    n_proj = bench_size(8, 4) if n_proj is None else n_proj
    geom, filt, mats, _ = ct_problem(L, n_proj=n_proj)
    # The timed pbatch rows run strip2 at its defaults — model exactly
    # that (empty opts resolve to the sampler defaults).
    sb = strip_bytes(geom, "strip2", {}, n_proj=n_proj)

    seq_bytes = volume_bytes(L, n_proj, 1)
    rows = {}
    for pb in sorted({min(pb, n_proj) for pb in PBATCHES}):
        t = time_fn(reconstruct, filt, mats, geom, strategy="strip2",
                    pbatch=pb, warmup=1, iters=2, min_total_s=0.3)
        vb = volume_bytes(L, n_proj, pb)
        rows[pb] = {"us": t * 1e6, "vol_bytes": vb, "strip_bytes": sb,
                    "vol_reduction": seq_bytes / vb}
        emit(f"table5/pbatch{pb}", t * 1e6,
             f"vol_mb={vb / 1e6:.3f} strip_mb={sb / 1e6:.3f} "
             f"vol_reduction={seq_bytes / vb:.2f} pbatch={pb} L={L} "
             f"nproj={n_proj}")

    # bf16 on the wire: same strip2 row at half the strip bytes, with
    # the quality cost measured (ROI PSNR of the bf16 volume against
    # the f32 one — the adversarial tolerance test in
    # tests/test_strip_dtype.py bounds the same number).
    pb_bf = min(DEFAULT_PBATCH, n_proj)
    bf_opts = {"strip_dtype": "bfloat16"}
    sb_bf = strip_bytes(geom, "strip2", bf_opts, n_proj=n_proj)
    t = time_fn(reconstruct, filt, mats, geom, strategy="strip2",
                pbatch=pb_bf, warmup=1, iters=2, min_total_s=0.3,
                **bf_opts)
    vol32 = np.asarray(reconstruct(filt, mats, geom, strategy="strip2",
                                   pbatch=pb_bf))
    vol16 = np.asarray(reconstruct(filt, mats, geom, strategy="strip2",
                                   pbatch=pb_bf, **bf_opts))
    psnr_db = float(psnr(vol16, vol32, roi_mask(L)))
    vb = volume_bytes(L, n_proj, pb_bf)
    emit("table5/bf16", t * 1e6,
         f"vol_mb={vb / 1e6:.3f} strip_mb={sb_bf / 1e6:.3f} "
         f"strip_reduction={sb / sb_bf:.2f} psnr_roi_db={psnr_db:.1f} "
         f"pbatch={pb_bf} L={L} nproj={n_proj}")

    # int8 on the wire (ROADMAP lever (b)): the same strip2 row again
    # at 1 byte/pixel codes plus the per-row scale sideband — the
    # modelled bytes count codes + scales, and the quality cost is
    # measured the same way as bf16's (ROI PSNR vs the f32 volume;
    # tests/test_strip_dtype.py asserts the > 35 dB floor).
    i8_opts = {"strip_dtype": "int8"}
    sb_i8 = strip_bytes(geom, "strip2", i8_opts, n_proj=n_proj)
    t = time_fn(reconstruct, filt, mats, geom, strategy="strip2",
                pbatch=pb_bf, warmup=1, iters=2, min_total_s=0.3,
                **i8_opts)
    vol8 = np.asarray(reconstruct(filt, mats, geom, strategy="strip2",
                                  pbatch=pb_bf, **i8_opts))
    psnr_i8_db = float(psnr(vol8, vol32, roi_mask(L)))
    emit("table5/int8", t * 1e6,
         f"vol_mb={vb / 1e6:.3f} strip_mb={sb_i8 / 1e6:.3f} "
         f"strip_reduction={sb / sb_i8:.2f} vs_bf16={sb_bf / sb_i8:.2f} "
         f"psnr_roi_db={psnr_i8_db:.1f} "
         f"pbatch={pb_bf} L={L} nproj={n_proj}")

    # The autotuner's decision for this geometry (fig1 runs the sweep
    # earlier in the module order; untuned keys fall back to the
    # default strategy/depth) — both terms modelled from the opts the
    # row *executes* after auto resolution.
    from repro.tune.cache import load_tuned, resolve_strategy

    gs = GeomStatic.of(geom)
    cfg = load_tuned(gs)
    chosen_strategy, chosen_opts = resolve_strategy(gs)
    chosen = int(chosen_opts.get("pbatch", DEFAULT_PBATCH))
    chosen = max(1, min(chosen, n_proj))
    sb_chosen = strip_bytes(geom, chosen_strategy, chosen_opts,
                            n_proj=n_proj)
    vb = volume_bytes(L, n_proj, chosen)
    t = time_fn(reconstruct, filt, mats, geom, strategy="auto",
                warmup=1, iters=2, min_total_s=0.3)
    emit("table5/chosen", t * 1e6,
         f"vol_mb={vb / 1e6:.3f} strip_mb={sb_chosen / 1e6:.3f} "
         f"vol_reduction={seq_bytes / vb:.2f} strategy={chosen_strategy} "
         f"pbatch={chosen} L={L} nproj={n_proj}")

    # Kernel-path strip model at the tuner's persisted Pallas tile
    # (defaults when untuned) — modelled, NOT timed: us=0 keeps the row
    # out of the regression gate, which only compares positive timings.
    from repro.kernels.backproject_ops import clamp_tiles

    ptile = dict(ty=8, chunk=min(32, L), band=16, width=128)
    pdtype = "float32"
    if cfg is not None and cfg.pallas:
        ptile.update({k: int(cfg.pallas[k])
                      for k in ("ty", "chunk", "band", "width")
                      if k in cfg.pallas})
        pdtype = str(cfg.pallas.get("strip_dtype", pdtype))
    kty, kchunk, kband, kwidth = clamp_tiles(gs, **ptile)
    kb = pallas_strip_bytes(geom, ty=kty, chunk=kchunk, band=kband,
                            width=kwidth, itemsize=_ITEMSIZE[pdtype],
                            n_proj=n_proj)
    emit("table5/kernel_model", 0.0,
         f"modelled-not-timed strip_mb={kb / 1e6:.3f} ty={kty} "
         f"chunk={kchunk} band={kband} width={kwidth} "
         f"strip_dtype={pdtype} L={L} nproj={n_proj}")

    # Shared superset window + bf16 wire, timed on the kernel path at
    # kernel-bench scale (interpret off-TPU, like fig1's kernel rows):
    # one slab DMA per (tile, projection group), half-width elements.
    from repro.kernels.backproject_ops import (pallas_backproject_batch,
                                               shared_window_dims)

    import jax.numpy as jnp

    Lk = bench_size(32, 16)
    geom_k, filt_k, mats_k, _ = ct_problem(Lk, n_proj=n_proj)
    gs_k = GeomStatic.of(geom_k)
    pbk = min(DEFAULT_PBATCH, n_proj)
    sty, schunk, sband0, swidth0 = clamp_tiles(gs_k, 8, min(32, Lk), 16,
                                               128)
    sband, swidth = shared_window_dims(geom_k, mats_k, ty=sty,
                                       chunk=schunk, pbatch=pbk)
    _, _, sband, swidth = clamp_tiles(gs_k, sty, schunk, sband, swidth)
    vol0_k = jnp.zeros((Lk,) * 3, jnp.float32)
    t = time_fn(pallas_backproject_batch, vol0_k, filt_k, mats_k, geom_k,
                ty=sty, chunk=schunk, pbatch=pbk, shared_window=True,
                strip_dtype="bfloat16", warmup=1, iters=2,
                min_total_s=0.3)
    kb_shared, dmas = shared_window_traffic(
        geom_k, ty=sty, chunk=schunk, band=sband, width=swidth,
        pbatch=pbk, itemsize=_ITEMSIZE["bfloat16"], n_proj=n_proj)
    kb_per_proj = pallas_strip_bytes(geom_k, ty=sty, chunk=schunk,
                                     band=sband0, width=swidth0,
                                     itemsize=_ITEMSIZE["bfloat16"],
                                     n_proj=n_proj)
    emit("table5/shared_bf16", t * 1e6,
         f"strip_mb={kb_shared / 1e6:.3f} strip_dmas={dmas} "
         f"sband={sband} swidth={swidth} "
         f"dma_reduction={pbk:.2f} pbatch={pbk} L={Lk} nproj={n_proj}")

    # Shared superset window + int8 wire: the slab DMA at 1 byte/pixel
    # plus the once-per-projection scale sideband (the scale block is
    # VMEM-resident per kernel call, not re-fetched per window).
    t = time_fn(pallas_backproject_batch, vol0_k, filt_k, mats_k, geom_k,
                ty=sty, chunk=schunk, pbatch=pbk, shared_window=True,
                strip_dtype="int8", warmup=1, iters=2,
                min_total_s=0.3)
    kb_shared_i8, dmas_i8 = shared_window_traffic(
        geom_k, ty=sty, chunk=schunk, band=sband, width=swidth,
        pbatch=pbk, itemsize=_ITEMSIZE["int8"], n_proj=n_proj)
    kb_shared_i8 += scale_sideband_bytes(geom_k, n_proj)
    emit("table5/shared_int8", t * 1e6,
         f"strip_mb={kb_shared_i8 / 1e6:.3f} strip_dmas={dmas_i8} "
         f"sband={sband} swidth={swidth} "
         f"vs_bf16={kb_shared / kb_shared_i8:.2f} "
         f"dma_reduction={pbk:.2f} pbatch={pbk} L={Lk} nproj={n_proj}")

    record_extra("table5_traffic", {
        "L": L, "n_proj": n_proj, "chosen_pbatch": chosen,
        "chosen_strategy": chosen_strategy,
        "volume_bytes_seq": seq_bytes,
        "volume_bytes_chosen": vb,
        "volume_reduction_chosen": seq_bytes / vb,
        "strip_bytes": sb,
        "strip_bytes_bf16": sb_bf,
        "strip_reduction_bf16": sb / sb_bf,
        "bf16_psnr_roi_db": psnr_db,
        "strip_bytes_int8": sb_i8,
        "strip_reduction_int8": sb / sb_i8,
        "int8_vs_bf16": sb_bf / sb_i8,
        "int8_psnr_roi_db": psnr_i8_db,
        "strip_bytes_chosen": sb_chosen,
        "kernel_model": {"ty": kty, "chunk": kchunk, "band": kband,
                         "width": kwidth, "strip_dtype": pdtype,
                         "strip_bytes": kb},
        "shared_window": {"L": Lk, "pbatch": pbk, "shared_band": sband,
                          "shared_width": swidth,
                          "strip_bytes": kb_shared,
                          "strip_bytes_per_projection_bf16": kb_per_proj,
                          "strip_bytes_int8": kb_shared_i8,
                          "strip_dmas": dmas,
                          "dma_reduction": pbk},
        "per_pbatch": {str(k): v for k, v in rows.items()},
    })


if __name__ == "__main__":
    run()
