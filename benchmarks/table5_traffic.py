"""Table 5 (beyond-paper): modelled volume HBM traffic vs measured time.

The back projection is memory-bound on its streaming part (the paper's
kernels sustain a handful of flops per voxel update; Treibig et al.,
arXiv:1104.5243, show throughput on real hardware is decided by the
volume-locality structure).  The loop-nest inversion of DESIGN.md §7
makes the dominant traffic term explicit:

* **volume**: each projection batch streams the ``L³`` f32 volume
  through memory once (read + write) —
  ``2 · ceil(n_proj / pbatch) · L³ · 4`` bytes;
* **projections**: one ``(band, width)`` strip DMA per (projection,
  volume tile) — ``n_proj · (L/ty) · (L/chunk) · L · band · width · 4``
  bytes on the kernel path, independent of ``pbatch``.

This module reports the modelled bytes *next to* the measured time per
``pbatch`` so the P× volume-traffic reduction is a committed number in
BENCH_ct.json, not an anecdote.  The ``table5/chosen`` row re-states the
model at the autotuner's persisted ``pbatch`` for this geometry.
"""

from __future__ import annotations

import math

from repro.core.backproject import DEFAULT_PBATCH, GeomStatic, reconstruct

from .common import bench_size, ct_problem, emit, record_extra, time_fn
from .fig1_single_device import PBATCHES

# Default kernel-path strip tile (matches the Pallas defaults at bench
# scale) for the projection-traffic term of the model.
_TY, _CHUNK, _BAND, _WIDTH = 8, 32, 16, 128


def volume_bytes(L: int, n_proj: int, pbatch: int) -> int:
    """Modelled volume HBM bytes per reconstruction (f32 read+write per
    volume pass; one pass per projection batch)."""
    return 2 * math.ceil(n_proj / pbatch) * L ** 3 * 4


def strip_bytes(L: int, n_proj: int, *, ty: int = _TY, chunk: int = _CHUNK,
                band: int = _BAND, width: int = _WIDTH) -> int:
    """Modelled projection-strip HBM bytes (kernel path): one
    ``(band, width)`` DMA per (projection, z, y-block, x-chunk) tile.
    Independent of ``pbatch`` — batching cuts only the volume term."""
    tiles = L * max(1, L // ty) * max(1, L // chunk)
    return n_proj * tiles * band * width * 4


def run(L: int | None = None, n_proj: int | None = None):
    L = bench_size(64, 16) if L is None else L
    n_proj = bench_size(8, 4) if n_proj is None else n_proj
    geom, filt, mats, _ = ct_problem(L, n_proj=n_proj)
    sb = strip_bytes(L, n_proj)

    seq_bytes = volume_bytes(L, n_proj, 1)
    rows = {}
    for pb in sorted({min(pb, n_proj) for pb in PBATCHES}):
        t = time_fn(reconstruct, filt, mats, geom, strategy="strip2",
                    pbatch=pb, warmup=1, iters=2)
        vb = volume_bytes(L, n_proj, pb)
        rows[pb] = {"us": t * 1e6, "vol_bytes": vb, "strip_bytes": sb,
                    "vol_reduction": seq_bytes / vb}
        emit(f"table5/pbatch{pb}", t * 1e6,
             f"vol_mb={vb / 1e6:.3f} strip_mb={sb / 1e6:.3f} "
             f"vol_reduction={seq_bytes / vb:.2f} pbatch={pb} L={L} "
             f"nproj={n_proj}")

    # The autotuner's decision for this geometry (fig1 runs the sweep
    # earlier in the module order; untuned keys fall back to the
    # default depth).
    from repro.tune.cache import load_tuned

    cfg = load_tuned(GeomStatic.of(geom))
    chosen = cfg.pbatch if cfg is not None else DEFAULT_PBATCH
    chosen = max(1, min(chosen, n_proj))
    vb = volume_bytes(L, n_proj, chosen)
    t = time_fn(reconstruct, filt, mats, geom, strategy="auto",
                warmup=1, iters=2)
    emit("table5/chosen", t * 1e6,
         f"vol_mb={vb / 1e6:.3f} strip_mb={sb / 1e6:.3f} "
         f"vol_reduction={seq_bytes / vb:.2f} pbatch={chosen} L={L} "
         f"nproj={n_proj}")
    record_extra("table5_traffic", {
        "L": L, "n_proj": n_proj, "chosen_pbatch": chosen,
        "volume_bytes_seq": seq_bytes,
        "volume_bytes_chosen": vb,
        "volume_reduction_chosen": seq_bytes / vb,
        "strip_bytes": sb,
        "per_pbatch": {str(k): v for k, v in rows.items()},
    })


if __name__ == "__main__":
    run()
