"""Shared benchmark utilities: timing, CSV+JSON emission, standard problems.

``time_fn`` is the single timing implementation shared with the autotuner
(``repro.tune.timing``) so tuned decisions and benchmark rows are
comparable numbers.  Every ``emit`` row is also collected into
:data:`RESULTS` (with ``key=value`` pairs in the derived column parsed
out) so ``benchmarks.run --json`` can persist a machine-readable
trajectory entry; :func:`record_extra` attaches structured extras such as
the autotuner's chosen config.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import Geometry, filter_projections
from repro.core.phantom import make_dataset
from repro.tune.timing import time_fn  # noqa: F401  (re-export)

# Tiny mode shrinks every standard problem to CI-sized shapes via
# ``bench_size`` (``benchmarks.run --tiny`` or REPRO_BENCH_TINY=1);
# moe_dispatch is laptop-sized by construction and takes no size knob.
# ``TINY_ENV`` is the immutable env-var default: ``run.main`` *assigns*
# ``TINY`` per invocation (tiny-ness must not latch across in-process
# runs), and the env opt-in has to survive that reset.
TINY_ENV = os.environ.get("REPRO_BENCH_TINY", "0") not in ("", "0")
TINY = TINY_ENV


def bench_size(normal, tiny):
    """Pick the CI-tiny or the paper-representative problem size."""
    return tiny if TINY else normal


RESULTS: list[dict] = []
EXTRAS: dict = {}


def _parse_derived(derived: str) -> dict:
    fields = {}
    for tok in derived.split():
        if "=" not in tok:
            continue
        k, _, v = tok.partition("=")
        try:
            fields[k] = float(v)
        except ValueError:
            fields[k] = v
    return fields


def emit(name: str, us_per_call: float, derived: str = ""):
    RESULTS.append({"name": name, "us_per_call": us_per_call,
                    "derived": derived,
                    "fields": _parse_derived(derived)})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def record_extra(key: str, value):
    """Attach a structured (JSON-serialisable) extra to this run."""
    EXTRAS[key] = value


_CACHE = {}


def ct_problem(L: int = 64, n_proj: int = 8):
    """Standard CT bench problem: filtered projections + matrices."""
    key = (L, n_proj)
    if key not in _CACHE:
        geom = Geometry().scaled(L, n_proj=n_proj)
        projs, mats, ref = make_dataset(geom)
        filt = np.asarray(filter_projections(projs, geom))
        _CACHE[key] = (geom, filt, mats, ref)
    return _CACHE[key]


STRATEGY_OPTS = {
    "scalar": {},
    "gather": {},
    "onehot": {"vox_block": 512},
    "strip": {"chunk": 32, "band": 16, "width": 128},
    "strip2": {"group": 8, "gband": 8, "gwidth": 64},
}
