"""Shared benchmark utilities: timing, CSV emission, standard problems."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import Geometry, filter_projections
from repro.core.phantom import make_dataset


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, **kw):
    """Median wall time (seconds) of jitted ``fn``; blocks on results."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


_CACHE = {}


def ct_problem(L: int = 64, n_proj: int = 8):
    """Standard CT bench problem: filtered projections + matrices."""
    key = (L, n_proj)
    if key not in _CACHE:
        geom = Geometry().scaled(L, n_proj=n_proj)
        projs, mats, ref = make_dataset(geom)
        filt = np.asarray(filter_projections(projs, geom))
        _CACHE[key] = (geom, filt, mats, ref)
    return _CACHE[key]


STRATEGY_OPTS = {
    "scalar": {},
    "gather": {},
    "onehot": {"vox_block": 512},
    "strip": {"chunk": 32, "band": 16, "width": 128},
    "strip2": {"group": 8, "gband": 8, "gwidth": 64},
}
