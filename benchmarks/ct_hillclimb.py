"""CT hillclimb (§Perf, paper-representative cell): per-iteration terms.

Measures every back projection configuration's per-voxel flops/bytes from
the lowered HLO and models the TPU roofline terms for the full RabbitCT
problem (512^3 x 496 on one v5e chip), mirroring the paper's section-6.4
cycle decomposition.  Iterations:

  CT-0  gather   (hardware-gather analogue — XLA gather HLO baseline)
  CT-1  strip    (paper-faithful fastrabbit scheme: block loads + banded
                  one-hot, band 16 x width 512)
  CT-2  strip2   (beyond-paper: two-level micro-windows 8x64)
  CT-3  strip2-s (shrunk windows 4x32 — napkin: ~2x fewer select flops)
  CT-4  +clip    (exact clipping mask: voxel-work reduction, applied as
                  the planner's active fraction)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import GATHER_DERATE, HBM_BW, PEAK_FLOPS
from repro.analysis.hlo_module import analyze_module
from repro.core.backproject import backproject_one
from repro.core.clipping import line_clip_exact

from .common import bench_size, ct_problem, emit

FULL = 512 ** 3 * 496

VARIANTS = [
    ("CT-0 gather", "gather", {}),
    ("CT-1 strip (paper-faithful)", "strip",
     {"chunk": 32, "band": 16, "width": 128}),
    ("CT-2 strip2 8x64", "strip2", {"group": 8, "gband": 8,
                                    "gwidth": 64}),
    ("CT-3 strip2 4x32", "strip2", {"group": 8, "gband": 4,
                                    "gwidth": 32}),
]


def run(L: int | None = None):
    L = bench_size(64, 16) if L is None else L
    geom, filt, mats, _ = ct_problem(L)
    vol0 = jnp.zeros((L,) * 3, jnp.float32)
    # Mid-sweep projection: the first one is Parker-weighted to ~zero.
    mid = len(mats) // 2
    image = jnp.asarray(filt[mid])
    A = jnp.asarray(mats[mid])
    voxels = L ** 3

    ref = np.asarray(backproject_one(vol0, image, A, geom,
                                     strategy="scalar"))
    scale = np.abs(ref).max()

    for name, strat, opts in VARIANTS:
        out = np.asarray(backproject_one(vol0, image, A, geom,
                                         strategy=strat, **opts))
        err = np.abs(out - ref).max() / scale
        txt = jax.jit(
            lambda v, i, a, s=strat, o=opts: backproject_one(
                v, i, a, geom, strategy=s, **o)
        ).lower(vol0, image, A).compile().as_text()
        an = analyze_module(txt)
        fl = an["flops"] / voxels
        by = an["bytes"] / voxels
        gb = an["gather_bytes"] / voxels
        tc = fl / PEAK_FLOPS
        # Streamed bytes at full bandwidth; gathered bytes derated
        # (Table-4-style serialisation; repro.analysis.hlo).
        tm = (by - gb) / HBM_BW + gb * GATHER_DERATE / HBM_BW
        bound = max(tc, tm)
        emit(f"ct_hillclimb/{name}", 0.0,
             f"flops_vox={fl:.0f} bytes_vox={by:.0f} "
             f"gather_bytes_vox={gb:.0f} "
             f"dominant={'compute' if tc > tm else 'memory'} "
             f"full_1chip_s={bound * FULL:.2f} "
             f"gups={FULL / (bound * FULL) / 1e9:.2f} "
             f"relerr={err:.1e}")

    # CT-4: clipping as work reduction on the best variant.
    act = np.mean([
        line_clip_exact(geom, np.asarray(m, np.float64)).voxels
        / voxels for m in mats])
    emit("ct_hillclimb/CT-4 +exact-clip", 0.0,
         f"active_fraction={act:.3f} "
         f"(multiplies the dominant term of the chosen variant)")

    # CT-5/6: Pallas-kernel models at production tiling.  The kernel's
    # strips arrive by DMA (streamed, no gather derate); compute terms
    # from the selection arithmetic.  Both kernels validated vs the
    # oracle in tests/test_kernel_backproject.py; interpret mode cannot
    # be timed, so these terms are analytic at the hardware constants.
    from repro.kernels.backproject_ops import pallas_backproject_one  # noqa: F401  (validated variant)
    ty, chunk, band, width = 8, 128, 16, 512
    micro_fl = 2 * 4 * 32 + 4 * 32 + 60
    for name, fl_vox, img_bytes in (
            ("CT-5 kernel strip 16x512 (DMA)",
             2 * band * width + 4 * width + 60, 4),
            ("CT-6 kernel micro 4x32 (DMA)", micro_fl, 4),
            ("CT-7 kernel micro + bf16 strips", micro_fl, 2)):
        by_vox = band * width * img_bytes / (ty * chunk) + 8.0
        tc = fl_vox / PEAK_FLOPS
        tm = by_vox / HBM_BW
        bound = max(tc, tm)
        emit(f"ct_hillclimb/{name}", 0.0,
             f"flops_vox={fl_vox} bytes_vox={by_vox:.0f} "
             f"dominant={'compute' if tc > tm else 'memory'} "
             f"full_1chip_s={bound * FULL:.2f} "
             f"gups={1 / bound / 1e9:.1f} (model; kernel validated "
             f"interpret=True)")
    tc7 = micro_fl / PEAK_FLOPS
    tm7 = (band * width * 2 / (ty * chunk) + 8.0) / HBM_BW
    emit("ct_hillclimb/CT-7+clip", 0.0,
         f"gups={1 / (max(tc7, tm7) * act) / 1e9:.1f} "
         f"with exact-clip work skip (x{1 / act:.2f})")


if __name__ == "__main__":
    run()
