"""Streamed reconstruction latency/throughput (beyond-paper figure).

The paper's C-arm delivers projections as a stream; what a clinic feels
is (a) **time-to-first-volume** — wall time from first arriving chunk to
the finished volume when filtering and back projection overlap the
arrival, and (b) **projections/s** sustained when B scans reconstruct
concurrently on one device (the streaming engine's continuous batching,
DESIGN.md §8).

Rows:

* ``fig4/ttfv/b1`` — one scan streamed chunk-by-chunk through a fresh
  engine; ``us_per_call`` is the full stream-to-volume latency.
* ``fig4/stream/b{B}`` — B interleaved scans, round-robin chunk
  arrival; derived ``projps`` counts every folded projection.

The engine's jitted filter/fold steps are module-level, so the warmup
run compiles once and every measured engine instance reuses the trace —
the numbers are steady-state serving, not compile time.
"""

from __future__ import annotations

import numpy as np

from repro.api import Geometry, ProjectionChunk, ReconstructionEngine
from repro.core.phantom import make_dataset

from .common import bench_size, emit, record_extra, time_fn

BATCHES = (1, 4, 8)


def _stream(geom, projs, mats, *, n_scans: int, chunk: int,
            pbatch: int) -> None:
    """Run ``n_scans`` concurrent streamed reconstructions to completion."""
    n_proj = projs.shape[0]
    eng = ReconstructionEngine(geom, n_slots=min(n_scans, 4),
                               pbatch=pbatch)
    sids = [eng.begin_scan(n_proj=n_proj) for _ in range(n_scans)]
    # Round-robin arrival: chunk c of every scan lands before chunk c+1
    # of any scan — the C-arm-per-room traffic shape.
    for c0 in range(0, n_proj, chunk):
        sel = slice(c0, min(c0 + chunk, n_proj))
        idx = np.arange(sel.start, sel.stop)
        for sid in sids:
            eng.submit(sid, ProjectionChunk(projs[sel], mats[sel], idx))
    eng.drain()
    vols = [eng.result(sid) for sid in sids]
    vols[-1].block_until_ready()
    return None


def run(L: int | None = None):
    L = bench_size(48, 12) if L is None else L
    n_proj = bench_size(32, 8)
    chunk = bench_size(4, 2)
    pbatch = 4
    geom = Geometry().scaled(L, n_proj=n_proj)
    projs, mats, _ = make_dataset(geom)
    projs = np.asarray(projs, np.float32)

    # Time-to-first-volume: one scan, chunks in arrival order, filter
    # overlapping fold — the latency a streamed caller observes.
    # iters=2 alone under-samples noisy hosts; the 0.3 s adaptive floor
    # keeps these gate-feeding rows on the same sampling discipline as
    # the fig1 kernel rows.
    t = time_fn(_stream, geom, projs, mats, n_scans=1, chunk=chunk,
                pbatch=pbatch, warmup=1, iters=2, min_total_s=0.3)
    emit("fig4/ttfv/b1", t * 1e6,
         f"projps={n_proj / t:.1f} L={L} nproj={n_proj} chunk={chunk} "
         f"pbatch={pbatch}")

    for B in BATCHES:
        t = time_fn(_stream, geom, projs, mats, n_scans=B, chunk=chunk,
                    pbatch=pbatch, warmup=1, iters=2, min_total_s=0.3)
        emit(f"fig4/stream/b{B}", t * 1e6,
             f"projps={B * n_proj / t:.1f} L={L} nproj={n_proj} "
             f"chunk={chunk} pbatch={pbatch} scans={B}")

    record_extra("fig4_streaming", {
        "L": L, "n_proj": n_proj, "chunk": chunk, "pbatch": pbatch,
        "batches": list(BATCHES)})


if __name__ == "__main__":
    run()
