"""Bench-trajectory regression gate (CI's ``bench-trajectory`` job).

Compares the freshest run entry in a just-produced ``--json`` file
against the most recent *committed* BENCH_ct.json entry for the same
``(backend, device_kind, tiny)`` identity, row by row (``us_per_call``
per emitted benchmark name).  The threshold is deliberately generous —
CI runners are noisy and shared — and µs-scale rows below ``--min-us``
are skipped outright (their medians are timer noise even after the
adaptive ``time_fn``).  No matching baseline (new device kind, first
run) passes with a notice: the gate compares like with like or not at
all.

``python -m benchmarks.check_regression --baseline BENCH_ct.json \
    --fresh bench.json [--threshold 2.5] [--min-us 2500]``

Exit status: 0 = no regression (or nothing comparable), 1 = at least
one row regressed past the threshold, 2 = bad invocation/unreadable
input.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# The gate parameters — ONE source of truth, used both as the CLI
# defaults below and by .github/workflows/ci.yml (which passes no
# overrides), so a local ``python -m benchmarks.check_regression`` run
# reaches the same verdict CI does.  2.5x absorbs shared-runner noise
# without masking a real 3x cliff; rows whose baseline median is under
# 2.5 ms are timer noise on those runners and are skipped outright.
GATE_THRESHOLD = 2.5
GATE_MIN_US = 2500.0


def _load_runs(path: str) -> list[dict] | None:
    """Runs list, ``[]`` for a missing file, ``None`` for an unreadable
    one — a *corrupt* committed baseline must fail the gate loudly, not
    disable it by looking like 'no baseline'."""
    p = Path(path)
    if not p.is_file():
        return []
    try:
        doc = json.loads(p.read_text())
    except json.JSONDecodeError:
        return None
    runs = doc.get("runs") if isinstance(doc, dict) else None
    return runs if isinstance(runs, list) else None


def _identity(run: dict) -> tuple:
    meta = run.get("meta", {})
    return (meta.get("backend"), meta.get("device_kind"),
            bool(meta.get("tiny")))


def _rows(run: dict) -> dict[str, float]:
    out = {}
    for row in run.get("rows", []):
        name = row.get("name")
        us = row.get("us_per_call")
        if isinstance(name, str) and isinstance(us, (int, float)) and us > 0:
            out[name] = float(us)
    return out


def compare(baseline_run: dict, fresh_run: dict, *, threshold: float,
            min_us: float
            ) -> tuple[list[tuple[str, float, float]], int, list[str]]:
    """Return (regressions, n_compared, missing); a regression is
    ``(row name, baseline us, fresh us)``, ``missing`` the baseline rows
    above ``min_us`` that the fresh run did not emit at all (a crashed
    benchmark module drops its rows — that must not read as a pass).

    A sub-``min_us`` median is timer noise whichever file it sits in:
    a baseline below the floor is never a denominator (a noise-scale
    baseline under an above-floor fresh row would fail on nothing but
    the baseline's jitter), and a fresh row below the floor is never a
    numerator (it can only ever look like an improvement, which the
    gate doesn't score) — incomparable in *both* directions, skipped
    outright.
    """
    base = _rows(baseline_run)
    fresh = _rows(fresh_run)
    regressions = []
    missing = []
    n = 0
    for name, base_us in sorted(base.items()):
        if base_us < min_us:
            continue
        fresh_us = fresh.get(name)
        if fresh_us is None:
            missing.append(name)
            continue
        if fresh_us < min_us:
            continue
        n += 1
        if fresh_us > threshold * base_us:
            regressions.append((name, base_us, fresh_us))
    return regressions, n, missing


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed trajectory (BENCH_ct.json)")
    ap.add_argument("--fresh", required=True,
                    help="just-produced --json file to gate")
    ap.add_argument("--threshold", type=float, default=GATE_THRESHOLD,
                    help="fail when fresh > threshold * baseline")
    ap.add_argument("--min-us", type=float, default=GATE_MIN_US,
                    help="skip rows whose baseline is below this (noise)")
    args = ap.parse_args(argv)

    fresh_runs = _load_runs(args.fresh)
    if not fresh_runs:
        print(f"no runs in {args.fresh}; nothing to gate", file=sys.stderr)
        raise SystemExit(2)
    fresh_run = fresh_runs[-1]
    ident = _identity(fresh_run)

    baseline_runs = _load_runs(args.baseline)
    if baseline_runs is None:
        print(f"baseline {args.baseline} is unreadable; refusing to pass "
              f"vacuously", file=sys.stderr)
        raise SystemExit(2)
    candidates = [r for r in baseline_runs if _identity(r) == ident]
    if not candidates:
        print(f"# no committed baseline for backend/device_kind/tiny="
              f"{ident}; gate passes vacuously")
        return
    baseline_run = candidates[-1]

    regressions, n, missing = compare(baseline_run, fresh_run,
                                      threshold=args.threshold,
                                      min_us=args.min_us)
    print(f"# compared {n} row(s) against baseline "
          f"{baseline_run.get('timestamp', '?')} (threshold "
          f"{args.threshold}x, min {args.min_us}us)")
    for name in missing:
        print(f"MISSING {name}: baseline row above {args.min_us}us not "
              f"emitted by the fresh run")
    for name, base_us, fresh_us in regressions:
        print(f"REGRESSION {name}: {base_us:.1f}us -> {fresh_us:.1f}us "
              f"({fresh_us / base_us:.2f}x)")
    if regressions:
        raise SystemExit(1)
    if n == 0 and missing:
        # The fresh run dropped every comparable baseline row (a crashed
        # benchmark module emits nothing) — that is a gate failure, not
        # a vacuous pass.
        print(f"# zero rows compared; {len(missing)} baseline row(s) "
              f"missing from the fresh run", file=sys.stderr)
        raise SystemExit(1)
    print("# no regressions")


if __name__ == "__main__":
    main()
