"""Dispatch resolution cost: first-call selection vs warm lookup.

The dispatcher's promise (DESIGN.md §11) is a one-time price: an
untuned ``strategy="auto"`` pays one in-situ candidate sweep on first
call, then every later resolve — in this process or any other — is a
dict lookup.  This module prices both sides of that promise so the
trajectory file catches either one regressing:

* ``fig1/dispatch/cold`` — resolve against an *empty* tune dir with
  in-situ selection enabled: shortlist construction, one timed sample
  per candidate, schema-v4 persistence.  Median over repeated
  fresh-dir resolves; the candidate jit caches are process-wide, so
  the first sample carries the compiles and the median reports the
  steady re-selection cost (what a new geometry pays on a warmed-up
  server).
* ``fig1/dispatch/warm`` — the cache-hit resolve on the same key
  (memo + plan construction), the per-call overhead every
  ``reconstruct(strategy="auto")`` pays forever after.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import Geometry

from .common import bench_size, emit, record_extra, time_fn

COLD_SAMPLES = 3


def run(L: int | None = None):
    from repro.dispatch import Dispatcher, reset_dispatcher
    from repro.tune import clear_memory_cache

    L = bench_size(32, 16) if L is None else L
    n_proj = bench_size(8, 4)
    geom = Geometry().scaled(L, n_proj=n_proj)

    saved_dir = os.environ.get("REPRO_TUNE_DIR")
    tmp = tempfile.mkdtemp(prefix="repro-dispatch-bench-")
    try:
        cold = []
        plan = None
        for i in range(COLD_SAMPLES):
            d = os.path.join(tmp, f"cold{i}")
            os.environ["REPRO_TUNE_DIR"] = d
            clear_memory_cache()
            disp = Dispatcher(insitu=True, include_pallas=False)
            t0 = time.perf_counter()
            plan = disp.resolve(geom)
            cold.append(time.perf_counter() - t0)
        cold_s = float(np.median(cold))
        emit("fig1/dispatch/cold", cold_s * 1e6,
             f"L={L} nproj={n_proj} samples={COLD_SAMPLES} "
             f"winner={plan.label}")

        # Warm: the tune dir of the last cold resolve already holds the
        # decision; a fresh dispatcher hits disk once, then the memo.
        clear_memory_cache()
        disp = Dispatcher(insitu=False)
        # Gate-feeding rows sample with min_total_s=0.3 (PR 6 rule):
        # every row the regression gate may compare must integrate at
        # least 0.3 s of samples, or its median is runner noise and the
        # gate threshold gates jitter instead of code.
        warm_s = time_fn(disp.resolve, geom, warmup=2, iters=20,
                         min_total_s=0.3)
        assert disp.resolve(geom) == plan
        emit("fig1/dispatch/warm", warm_s * 1e6,
             f"L={L} nproj={n_proj} winner={plan.label}")

        record_extra("dispatch", {
            "plan": plan.as_dict(),
            "cold_us": cold_s * 1e6,
            "cold_samples_us": [t * 1e6 for t in cold],
            "warm_us": warm_s * 1e6,
        })
    finally:
        if saved_dir is None:
            os.environ.pop("REPRO_TUNE_DIR", None)
        else:
            os.environ["REPRO_TUNE_DIR"] = saved_dir
        clear_memory_cache()
        reset_dispatcher()
        shutil.rmtree(tmp, ignore_errors=True)
