"""The technique on the LM side: embedding-gather strategy comparison.

Applies the paper's gather-strategy question to the assigned archs'
vocabulary tables (52k-256k rows): XLA gather vs one-hot MXU gather vs
the Pallas one-hot kernel, timed on this backend at a scaled-down table
and censused at full scale (zero gather HLOs in the onehot lowering —
checked, not assumed).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.hlo_module import analyze_module
from repro.core.gather_ops import onehot_gather, take_gather
from repro.kernels.gather_kernel_ops import pallas_onehot_gather

from .common import bench_size, emit, time_fn


def run(V: int | None = None, D: int | None = None,
        N: int | None = None):
    V = bench_size(8192, 1024) if V is None else V
    D = bench_size(256, 64) if D is None else D
    N = bench_size(2048, 256) if N is None else N
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (V, D), jnp.float32)
    ids = jax.random.randint(key, (N,), 0, V)

    t_take = time_fn(jax.jit(take_gather), table, ids)
    t_oh = time_fn(jax.jit(lambda t, i: onehot_gather(t, i, chunk=2048)),
                   table, ids)
    emit("lm_gather/take", t_take * 1e6, f"V={V} D={D} N={N}")
    emit("lm_gather/onehot", t_oh * 1e6,
         f"ratio_vs_take={t_oh / t_take:.1f}x")
    out_p = pallas_onehot_gather(table, ids)
    err = float(jnp.max(jnp.abs(out_p - take_gather(table, ids))))
    emit("lm_gather/pallas_onehot", 0.0,
         f"maxerr={err:.1e} interpret=True")

    # Census at full nemotron-scale vocabulary (no timing, no alloc).
    big = jax.ShapeDtypeStruct((256_000, 1024), jnp.bfloat16)
    bids = jax.ShapeDtypeStruct((4096,), jnp.int32)
    for name, fn in (("take", take_gather),
                     ("onehot", lambda t, i: onehot_gather(t, i, 8192))):
        txt = jax.jit(fn).lower(big, bids).compile().as_text()
        a = analyze_module(txt)
        emit(f"lm_gather/census_{name}", 0.0,
             f"gather_ops={a['census'].get('gather', 0)} "
             f"flops={a['flops']:.2e} bytes={a['bytes']:.2e}")


if __name__ == "__main__":
    run()
