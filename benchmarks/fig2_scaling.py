"""Paper Fig. 2 analogue: full-system scaling of the reconstruction.

The paper scales across cores (93% parallel efficiency, "highly
core-bound").  Here: ``shard_map`` reconstruction over an N-device mesh
(subprocess with fake CPU devices so the parent process keeps 1 device),
volume z-planes over ``data`` x projections over ``model`` — plus the
collective-bytes model for the production mesh from the same code path.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import bench_size, emit

_CHILD = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%(ndev)d")
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import Geometry, filter_projections, sharded_reconstruct
    from repro.core.phantom import make_dataset
    from repro.launch.mesh import make_local_mesh

    L, n_proj = %(L)d, %(n_proj)d
    geom = Geometry().scaled(L, n_proj=n_proj)
    projs, mats, ref = make_dataset(geom)
    filt = np.asarray(filter_projections(projs, geom))
    mesh = make_local_mesh(data=%(data)d, model=%(model)d)
    def run():
        return sharded_reconstruct(filt, mats, geom, mesh,
                                   strategy="gather")
    out = run(); jax.block_until_ready(out)       # compile+warm
    t0 = time.perf_counter()
    out = run(); jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(json.dumps({"dt": dt,
                      "sum": float(jnp.sum(out))}))
""")


def run(L: int | None = None, n_proj: int | None = None):
    L = bench_size(48, 16) if L is None else L
    n_proj = bench_size(8, 4) if n_proj is None else n_proj
    results = {}
    for ndev, data, model in [(1, 1, 1), (2, 2, 1), (4, 2, 2),
                              (8, 4, 2)]:
        script = _CHILD % {"ndev": ndev, "L": L, "n_proj": n_proj,
                           "data": data, "model": model}
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=600,
                             cwd=os.path.dirname(os.path.dirname(
                                 os.path.abspath(__file__))))
        if out.returncode != 0:
            emit(f"fig2/ndev={ndev}", 0.0,
                 f"ERROR {out.stderr.strip()[-120:]}")
            continue
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        results[ndev] = rec
        base = results.get(1, rec)["dt"]
        # Single host CPU: ideal scaling is flat wall time (devices share
        # one core); the check is correctness + collective plumbing, the
        # paper-style efficiency number is meaningful on real chips.
        emit(f"fig2/ndev={ndev}", rec["dt"] * 1e6,
             f"checksum={rec['sum']:.2f} rel_time={rec['dt'] / base:.2f} "
             f"mesh={data}x{model}")


if __name__ == "__main__":
    run()
