"""Paper Fig. 3 analogue: "compiler-generated" vs hand-structured kernels.

The paper compares icc/ISPC auto-vectorised C against hand-written
assembly (hand-written wins 10-34%).  The JAX analogue: the *naive
transliteration* of Listing 1 (``scalar`` — what you'd write without
thinking about the backend, XLA auto-vectorises it) against the
hand-structured strategies, plus the Pallas kernel (interpret mode:
correctness + op census only; wall time on CPU is meaningless for a
TPU-target kernel, so its column reports census/flops instead).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_module import analyze_module
from repro.core.backproject import backproject_one
from repro.kernels.backproject_ops import pallas_backproject_one
from repro.kernels.backproject_ref import backproject_volume_ref
from repro.core.backproject import GeomStatic

from .common import bench_size, ct_problem, emit, time_fn, STRATEGY_OPTS


def run(L: int | None = None):
    L = bench_size(64, 16) if L is None else L
    geom, filt, mats, _ = ct_problem(L)
    vol0 = jnp.zeros((L,) * 3, jnp.float32)
    image = jnp.asarray(filt[0])
    A = jnp.asarray(mats[0])

    t_naive = time_fn(backproject_one, vol0, image, A, geom,
                      strategy="scalar", warmup=1, iters=3)
    emit("fig3/compiler(scalar-jnp)", t_naive * 1e6,
         f"gups={L ** 3 / t_naive / 1e9:.4f}")
    for strat in ("gather", "strip", "strip2"):
        t = time_fn(backproject_one, vol0, image, A, geom,
                    strategy=strat, warmup=1, iters=3,
                    **STRATEGY_OPTS[strat])
        emit(f"fig3/hand({strat})", t * 1e6,
             f"gups={L ** 3 / t / 1e9:.4f} "
             f"vs_compiler={t_naive / t:.2f}x")

    # Pallas kernel: correctness vs oracle + structural census.
    out_k = pallas_backproject_one(vol0, image, A, geom, ty=8,
                                   chunk=32, band=16, width=128)
    gs = GeomStatic.of(geom)
    out_r = backproject_volume_ref(vol0, image, A, gs)
    err = float(jnp.max(jnp.abs(out_k - out_r)))
    emit("fig3/pallas(strip-kernel)", 0.0,
         f"maxerr_vs_oracle={err:.2e} interpret=True "
         f"(TPU-target; CPU wall time n/a)")


if __name__ == "__main__":
    run()
