"""Fused sLSTM kernel vs the XLA-scan oracle: shape/dtype sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels.slstm_ops import fused_slstm_forward
from repro.models.layers import Param
from repro.models.ssm import init_slstm, slstm_forward


def _cfg(d=32, expand=2):
    return ModelConfig(name="t", family="ssm", n_layers=2, d_model=d,
                       n_heads=4, n_kv_heads=2, d_ff=0, vocab=64,
                       ssm_expand=expand, param_dtype="float32")


@pytest.mark.parametrize("B,S,d", [(2, 16, 32), (3, 40, 16),
                                   (8, 64, 64)])
def test_fused_matches_scan(B, S, d):
    cfg = _cfg(d)
    p = Param(jax.random.PRNGKey(0), jnp.float32)
    init_slstm(p, cfg)
    params = p.params
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d),
                          jnp.float32) * 0.5
    ref = slstm_forward(params, cfg, x, dtype=jnp.float32)
    out = fused_slstm_forward(params, cfg, x, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_fused_bf16_close():
    cfg = _cfg(32)
    p = Param(jax.random.PRNGKey(0), jnp.float32)
    init_slstm(p, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32),
                          jnp.float32) * 0.5
    ref = slstm_forward(p.params, cfg, x, dtype=jnp.float32)
    out = fused_slstm_forward(p.params, cfg, x, dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=5e-2, atol=5e-2)
