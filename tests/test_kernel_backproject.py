"""Pallas back projection kernel: shape/dtype sweep vs the pure-jnp oracle.

Required kernel validation: sweep shapes and dtypes, assert_allclose
against backproject_ref (interpret=True on CPU).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Geometry, filter_projections
from repro.core.backproject import GeomStatic
from repro.core.geometry import projection_matrix
from repro.core.phantom import make_dataset
from repro.kernels.backproject_ops import (pallas_backproject_one,
                                           validate_strip_config)
from repro.kernels.backproject_ref import backproject_volume_ref


def _problem(L, n_proj=2):
    geom = Geometry().scaled(L, n_proj=n_proj)
    projs, mats, _ = make_dataset(geom)
    filt = np.asarray(filter_projections(projs, geom))
    return geom, filt, mats


@pytest.mark.parametrize("L,ty,chunk,band,width", [
    (16, 4, 16, 16, 128),
    (16, 8, 8, 16, 128),
    (32, 8, 32, 16, 128),
    (32, 4, 16, 24, 256),
])
def test_kernel_shape_sweep(L, ty, chunk, band, width):
    geom, filt, mats = _problem(L)
    gs = GeomStatic.of(geom)
    vol0 = jnp.zeros((L,) * 3, jnp.float32)
    out_k = pallas_backproject_one(vol0, filt[0], mats[0], geom, ty=ty,
                                   chunk=chunk, band=band, width=width,
                                   validate=True)
    out_r = backproject_volume_ref(vol0, filt[0], mats[0], gs)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("variant", [{"double_buffer": True},
                                     {"double_buffer": True,
                                      "db_depth": 4},
                                     {"micro": True}])
def test_kernel_variants_match_oracle(variant):
    """CT-3 double-buffer (classical and deep rotation) and CT-5
    micro-window vs the oracle."""
    geom, filt, mats = _problem(32, n_proj=4)
    gs = GeomStatic.of(geom)
    vol0 = jnp.zeros((32,) * 3, jnp.float32)
    k = 2                      # mid-sweep (projection 0 is Parker~0)
    out = pallas_backproject_one(vol0, filt[k], mats[k], geom, ty=8,
                                 chunk=32, band=16, width=128, **variant)
    ref = backproject_volume_ref(vol0, filt[k], mats[k], gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("variant", [{}, {"double_buffer": True},
                                     {"double_buffer": True,
                                      "db_depth": 3},
                                     {"micro": True}])
def test_kernel_variants_border_rays_vs_scalar_oracle(variant):
    """Interpret-mode parity of all three variants on the border-ray
    geometry of tests/test_strategy_sweep.py: taps straddling the
    detector edge must blend with implicit zeros in the kernel too."""
    from repro.core.backproject import backproject_one

    geom = Geometry().scaled(16, n_proj=8, n_u=24, n_v=18)
    rng = np.random.default_rng(3)
    image = jnp.asarray(rng.standard_normal((geom.n_v, geom.n_u)),
                        jnp.float32)
    A = jnp.asarray(projection_matrix(geom, 1.1), jnp.float32)
    vol0 = jnp.zeros((geom.L,) * 3, jnp.float32)
    ref = np.asarray(backproject_one(vol0, image, A, geom,
                                     strategy="scalar"))
    out = np.asarray(pallas_backproject_one(
        vol0, image, A, geom, ty=8, chunk=16, band=16, width=128,
        validate=True, **variant))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    # Border geometry must exercise both zero and nonzero voxels.
    assert (ref == 0.0).any() and (ref != 0.0).any()


@pytest.mark.parametrize("img_dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtype_sweep(img_dtype):
    geom, filt, mats = _problem(16)
    gs = GeomStatic.of(geom)
    vol0 = jnp.zeros((16,) * 3, jnp.float32)
    img = jnp.asarray(filt[0], img_dtype)
    out_k = pallas_backproject_one(vol0, img, mats[0], geom, ty=4,
                                   chunk=16, band=16, width=128)
    out_r = backproject_volume_ref(vol0, img.astype(jnp.float32),
                                   mats[0], gs)
    tol = 1e-5 if img_dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r),
        rtol=tol, atol=tol * float(jnp.max(jnp.abs(out_r))))


def test_kernel_int8_wire_differs_but_bounded():
    """int8 per-row affine codes on the kernel wire (plain / db /
    micro): observably different from f32 (the quantisation is real),
    within ~2% of the volume scale (the post-gather f32 dequant +
    f32-accumulate contract), and **bitwise identical across variants**
    — every variant dequantises the same codes with the same per-row
    scales, so DMA shape must not change the arithmetic."""
    geom, filt, mats = _problem(32, n_proj=4)
    vol0 = jnp.zeros((32,) * 3, jnp.float32)
    k = 2                      # mid-sweep (projection 0 is Parker~0)
    base = dict(ty=8, chunk=32, band=16, width=128)
    f32 = np.asarray(pallas_backproject_one(vol0, filt[k], mats[k],
                                            geom, **base))
    scale = float(np.abs(f32).max())
    outs = []
    for variant in ({}, {"double_buffer": True}, {"micro": True}):
        i8 = np.asarray(pallas_backproject_one(
            vol0, filt[k], mats[k], geom, strip_dtype="int8", **base,
            **variant))
        assert not np.array_equal(i8, f32), \
            f"int8 wire was a no-op under {variant}"
        assert float(np.abs(i8 - f32).max()) < 0.02 * scale
        outs.append(i8)
    for other in outs[1:]:
        np.testing.assert_array_equal(outs[0], other)


def test_kernel_accumulates_over_projections():
    geom, filt, mats = _problem(16, n_proj=3)
    gs = GeomStatic.of(geom)
    vol_k = jnp.zeros((16,) * 3, jnp.float32)
    vol_r = jnp.zeros((16,) * 3, jnp.float32)
    for k in range(3):
        vol_k = pallas_backproject_one(vol_k, filt[k], mats[k], geom,
                                       ty=4, chunk=16, band=16, width=128)
        vol_r = backproject_volume_ref(vol_r, filt[k], mats[k], gs)
    np.testing.assert_allclose(np.asarray(vol_k), np.asarray(vol_r),
                               rtol=1e-4, atol=1e-4)


def test_validate_rejects_undersized_strips():
    geom, filt, mats = _problem(32)
    with pytest.raises(ValueError, match="does not cover"):
        validate_strip_config(geom, np.asarray(mats[0], np.float64),
                              ty=32, chunk=32, band=8, width=128)


def test_micro_window_is_loud_or_correct():
    """The micro variant's tap-drop hazard (the ``micro_band=4`` default,
    same bug class as jnp strip2's old ``gband=4``): at L=48 the per-
    group footprint outgrows a 4-row window, so an undersized micro
    window must raise loudly at validation — and the bumped default must
    validate *and* match the scalar oracle."""
    from repro.core.backproject import backproject_one
    from repro.core.geometry import projection_matrix

    geom = Geometry().scaled(48, n_proj=4)
    rng = np.random.default_rng(7)
    image = jnp.asarray(rng.standard_normal((geom.n_v, geom.n_u)),
                        jnp.float32)
    A = jnp.asarray(projection_matrix(geom, 2.9), jnp.float32)
    A64 = np.asarray(A, np.float64)

    # Strip itself is large enough; the micro window is the problem.
    validate_strip_config(geom, A64, ty=8, chunk=48, band=32, width=256)
    with pytest.raises(ValueError, match="micro window"):
        validate_strip_config(geom, A64, ty=8, chunk=48, band=32,
                              width=256, micro=True, micro_band=4)
    with pytest.raises(ValueError, match="micro window"):
        pallas_backproject_one(
            jnp.zeros((48,) * 3, jnp.float32), image, A, geom, ty=8,
            chunk=48, band=32, width=256, micro=True, micro_band=4,
            validate=True)

    # Default micro window (8) validates and matches the oracle.
    vol0 = jnp.zeros((48,) * 3, jnp.float32)
    ref = np.asarray(backproject_one(vol0, image, A, geom,
                                     strategy="scalar"))
    assert np.abs(ref).max() > 0
    out = np.asarray(pallas_backproject_one(
        vol0, image, A, geom, ty=8, chunk=48, band=32, width=256,
        micro=True, validate=True))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_kernel_variants_are_exclusive():
    """micro + double_buffer on the single-projection path raises like
    the batch path does — a tuned decision names exactly one variant,
    so silently preferring either would misattribute its numbers."""
    geom, filt, mats = _problem(16)
    vol0 = jnp.zeros((16,) * 3, jnp.float32)
    with pytest.raises(ValueError, match="exclusive"):
        pallas_backproject_one(vol0, filt[0], mats[0], geom, ty=4,
                               chunk=16, band=16, width=128, micro=True,
                               double_buffer=True)


def test_micro_group_must_divide_chunk():
    geom, filt, mats = _problem(32)
    with pytest.raises(ValueError, match="must divide"):
        validate_strip_config(geom, np.asarray(mats[0], np.float64),
                              ty=8, chunk=32, band=16, width=128,
                              micro=True, micro_group=12)


def test_gather_kernel_sweep():
    """One-hot gather kernel vs oracle across shapes/dtypes."""
    import jax
    from repro.kernels.gather_kernel_ops import pallas_onehot_gather
    from repro.kernels.gather_ref import gather_ref
    key = jax.random.PRNGKey(1)
    for V, D, N, dt in [(300, 32, 17, jnp.float32),
                        (1024, 128, 512, jnp.float32),
                        (513, 64, 100, jnp.bfloat16)]:
        table = jax.random.normal(key, (V, D), jnp.float32).astype(dt)
        ids = jax.random.randint(key, (N,), -2, V + 2)
        out = pallas_onehot_gather(table, ids)
        ref = gather_ref(table, ids)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=1e-5, atol=1e-5)
