"""Flash-decoding over SP shards == plain decode (subprocess mesh)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_sp_decode_matches_plain(kv_dtype):
    script = textwrap.dedent(f"""
        import os, json
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8")
        import sys; sys.path.insert(0, {os.path.join(ROOT, 'src')!r})
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.dist.sharding import ShardingRules, sharding_context
        from repro.launch.mesh import make_local_mesh
        from repro.models.model import decode_step, init_cache, init_model

        cfg = dataclasses.replace(ARCHS["chatglm3-6b"].reduced(),
                                  vocab=128, kv_cache_dtype={kv_dtype!r})
        params, _ = init_model(cfg, jax.random.PRNGKey(0))
        B, T = 2, 32
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, 3), 0, 128)

        def run(sp):
            cache = init_cache(cfg, B, max_len=T)
            lgs = []
            def steps():
                nonlocal cache
                out = []
                c = cache
                for i in range(3):
                    lg, c = decode_step(params, cfg, c, toks[:, i:i+1],
                                        jnp.int32(i))
                    out.append(lg)
                return out
            if sp:
                mesh = make_local_mesh(data=2, model=4)
                rules = ShardingRules(batch=("data",), fsdp=(),
                                      tp=("model",), sp=("model",),
                                      flash_decode=True)
                with sharding_context(mesh, rules):
                    return steps()
            return steps()

        a = run(False)
        b = run(True)
        diff = max(float(jnp.abs(x - y).max()) for x, y in zip(a, b))
        scale = float(jnp.abs(a[-1]).max())
        print(json.dumps({{"diff": diff, "scale": scale}}))
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=560,
                         cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    tol = 2e-3 if kv_dtype == "bf16" else 2e-2
    assert rec["diff"] < tol * max(rec["scale"], 1.0), rec
