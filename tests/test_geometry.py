"""Geometry properties: forward/back projection consistency, monotone beam."""

import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.geometry import (Geometry, detector_basis,
                                 project_voxels, projection_matrix,
                                 source_position)

GEOM = Geometry().scaled(32)


@given(theta=st.floats(0.0, 6.28), px=st.floats(-50.0, 50.0),
       py=st.floats(-50.0, 50.0), pz=st.floats(-50.0, 50.0))
@settings(max_examples=50, deadline=None)
def test_matrix_matches_pinhole(theta, px, py, pz):
    """A @ [X,1] reproduces the explicit pinhole projection."""
    A = projection_matrix(GEOM, theta)
    ix, iy, w = project_voxels(A, px, py, pz)
    e_u, e_v, e_w = detector_basis(GEOM, theta)
    s = source_position(GEOM, theta)
    rel = np.array([px, py, pz]) - s
    z_cam = float(rel @ e_w)
    ix_ref = GEOM.sdd / GEOM.du * float(rel @ e_u) / z_cam + GEOM.cu
    iy_ref = GEOM.sdd / GEOM.dv * float(rel @ e_v) / z_cam + GEOM.cv
    np.testing.assert_allclose(ix, ix_ref, rtol=1e-9, atol=1e-7)
    np.testing.assert_allclose(iy, iy_ref, rtol=1e-9, atol=1e-7)
    np.testing.assert_allclose(w, z_cam / GEOM.sid, rtol=1e-9)


def test_isocenter_w_is_one():
    for theta in np.linspace(0, 2 * np.pi, 7):
        A = projection_matrix(GEOM, theta)
        _, _, w = project_voxels(A, 0.0, 0.0, 0.0)
        np.testing.assert_allclose(w, 1.0, rtol=1e-12)
        ix, iy, _ = project_voxels(A, 0.0, 0.0, 0.0)
        np.testing.assert_allclose(ix, GEOM.cu, atol=1e-6)
        np.testing.assert_allclose(iy, GEOM.cv, atol=1e-6)


@given(theta=st.floats(0.0, 6.28),
       y=st.integers(0, GEOM.L - 1), z=st.integers(0, GEOM.L - 1))
@settings(max_examples=50, deadline=None)
def test_monotone_beam(theta, y, z):
    """ix(x) and iy(x) are monotone along a voxel line (w > 0 region).

    The property the strip planner's exactness rests on (DESIGN.md §2,
    clipping.py docstring).
    """
    A = projection_matrix(GEOM, theta)
    xs = np.arange(GEOM.L, dtype=np.float64)
    wx = GEOM.O + xs * GEOM.MM
    wy = GEOM.O + y * GEOM.MM
    wz = GEOM.O + z * GEOM.MM
    ix, iy, w = project_voxels(A, wx, np.full_like(wx, wy),
                               np.full_like(wx, wz))
    assert (w > 0).all(), "sane geometry keeps the volume in front"
    dix = np.diff(ix)
    diy = np.diff(iy)
    assert (dix >= -1e-9).all() or (dix <= 1e-9).all()
    assert (diy >= -1e-9).all() or (diy <= 1e-9).all()


def test_forward_project_matches_matrix_geometry():
    """A ray cast through pixel (ix,iy) hits detector coords (ix,iy)."""
    from repro.core.phantom import Ellipsoid, forward_project
    # A tiny ellipsoid at a known offset: its projection peak must land
    # where the matrix projects its centre.
    center = (20.0, -10.0, 5.0)
    ell = Ellipsoid(center, (3.0, 3.0, 3.0), 1.0)
    theta = 0.7
    proj = forward_project(GEOM, [ell], np.array([theta]))[0]
    A = projection_matrix(GEOM, theta)
    ix, iy, _ = project_voxels(A, *center)
    peak = np.unravel_index(np.argmax(proj), proj.shape)
    assert abs(peak[1] - ix) <= 1.5
    assert abs(peak[0] - iy) <= 1.5
