"""int8 KV cache: decode matches the bf16-cache path within quant error."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models.model import decode_step, init_cache, init_model, prefill


def test_int8_cache_decode_close():
    base = dataclasses.replace(ARCHS["chatglm3-6b"].reduced(), vocab=128)
    q8 = dataclasses.replace(base, kv_cache_dtype="int8")
    params, _ = init_model(base, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    B, n = 2, 10
    toks = jax.random.randint(key, (B, n + 4), 0, base.vocab)

    outs = {}
    for name, cfg in (("bf16", base), ("int8", q8)):
        _, cache = prefill(params, cfg, {"tokens": toks[:, :n]},
                           max_len=32)
        lg, cache = decode_step(params, cfg, cache, toks[:, n:n + 1],
                                jnp.int32(n))
        lg2, _ = decode_step(params, cfg, cache, toks[:, n + 1:n + 2],
                             jnp.int32(n + 1))
        outs[name] = np.asarray(lg2[:, 0], np.float32)

    a, b = outs["bf16"], outs["int8"]
    # Same argmax almost surely; logits close at the quantisation scale.
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.5
    rel = np.abs(a - b).max() / max(np.abs(a).max(), 1e-6)
    assert rel < 0.15, rel


def test_int8_cache_structure():
    cfg = dataclasses.replace(ARCHS["mistral-nemo-12b"].reduced(),
                              kv_cache_dtype="int8")
    cache = init_cache(cfg, batch=2, max_len=16)
    blk = cache["blocks"]["b0"]
    assert blk["k"].dtype == jnp.int8
    assert blk["k_s"].dtype == jnp.bfloat16
    assert blk["k_s"].shape[-1] == 1
    # int8 + scales ~= half the bf16 cache bytes
    b_int8 = sum(a.size * a.dtype.itemsize
                 for a in jax.tree.leaves(cache))
    cfg2 = dataclasses.replace(cfg, kv_cache_dtype="bf16")
    b_bf16 = sum(a.size * a.dtype.itemsize
                 for a in jax.tree.leaves(init_cache(cfg2, 2, 16)))
    assert b_int8 < 0.6 * b_bf16
