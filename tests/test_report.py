"""Coverage for repro.analysis.report: dryrun-record loading, the
duration formatter, and the rendered roofline/summary tables."""

import json

from repro.analysis.report import _fmt_s, load, main, roofline_table, summary


def _rec(arch="a100", shape="1b", mesh="pod", status="ok", **over):
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh, "status": status,
        "step": "train",
        "roofline": {"compute_s": 2e-3, "memory_s": 4e-3,
                     "collective_s": 5e-4, "dominant": "memory",
                     "bound_s": 4e-3},
        "useful_flops_ratio": 0.62,
        "memory": {"live_bytes": 12.8e9},
        "fits_16gb_hbm": True,
    }
    rec.update(over)
    return rec


# ----------------------------------------------------------------------
# load
# ----------------------------------------------------------------------

def test_load_reads_json_files_sorted(tmp_path):
    (tmp_path / "b.json").write_text(json.dumps(_rec(shape="8b")))
    (tmp_path / "a.json").write_text(json.dumps(_rec(shape="1b")))
    (tmp_path / "notes.txt").write_text("ignored")
    recs = load(str(tmp_path))
    assert [r["shape"] for r in recs] == ["1b", "8b"]


def test_load_empty_dir(tmp_path):
    assert load(str(tmp_path)) == []


# ----------------------------------------------------------------------
# _fmt_s
# ----------------------------------------------------------------------

def test_fmt_s_units():
    assert _fmt_s(0) == "0"
    assert _fmt_s(1.5) == "1.50s"
    assert _fmt_s(2.5e-3) == "2.50ms"
    assert _fmt_s(42e-6) == "42.00us"
    assert _fmt_s(7e-9) == "7.00ns"
    assert _fmt_s(3e-10) == "3.0e-10s"   # below ns: raw scientific


# ----------------------------------------------------------------------
# roofline_table / summary (golden)
# ----------------------------------------------------------------------

def test_roofline_table_golden():
    recs = [
        _rec(arch="h100", shape="8b", status="skipped"),
        _rec(),
        _rec(arch="h100", shape="1b", status="error",
             error="OOM during layout"),
        _rec(mesh="multipod"),            # filtered out by mesh
    ]
    table = roofline_table(recs, "pod")
    lines = table.splitlines()
    assert lines[0].startswith("| arch | shape | step |")
    # Sorted by (arch, shape); the multipod record is absent.
    assert len(lines) == 2 + 3
    assert lines[2] == ("| a100 | 1b | train | 2.00ms | 4.00ms | "
                        "500.00us | memory | 50.0% | 0.62 | 12.8 | "
                        "yes |")
    assert "ERROR" in lines[3] and lines[3].startswith("| h100 | 1b |")
    assert "skip" in lines[4] and lines[4].startswith("| h100 | 8b |")


def test_roofline_table_zero_bound_and_tight_memory():
    r = _rec(fits_16gb_hbm=False)
    r["roofline"]["bound_s"] = 0.0
    table = roofline_table([r], "pod")
    assert "| 0.0% |" in table          # bound_s=0 -> MFU reported 0
    assert "| NO |" in table            # over-budget HBM is shouted


def test_summary_counts_and_error_lines():
    recs = [_rec(), _rec(status="skipped"),
            _rec(status="error", error="x" * 200)]
    text = summary(recs)
    assert text.splitlines()[0] == "cells: 1 ok, 1 skipped, 1 error"
    err_line = text.splitlines()[1]
    assert err_line.startswith("  ERROR a100 1b pod:")
    assert len(err_line) <= len("  ERROR a100 1b pod: ") + 120


def test_main_renders_per_mesh_sections(tmp_path, capsys, monkeypatch):
    (tmp_path / "p.json").write_text(json.dumps(_rec()))
    (tmp_path / "m.json").write_text(json.dumps(_rec(mesh="multipod")))
    monkeypatch.setattr("sys.argv", ["report", str(tmp_path)])
    main()
    out = capsys.readouterr().out
    assert "cells: 2 ok, 0 skipped, 0 error" in out
    assert "### Roofline — mesh `pod` (256 chips)" in out
    assert "### Roofline — mesh `multipod` (512 chips)" in out
