"""Benchmark harness: --only validation and the --json perf trajectory."""

import json

import pytest

from benchmarks import common
from benchmarks import run as bench_run
from repro.core.backproject import STRATEGIES
from repro.tune import clear_memory_cache


def test_only_typo_lists_modules_and_exits_nonzero(capsys):
    """An unknown --only name must not print a lone CSV header and
    exit 0 (the old behaviour); it lists valid modules and fails."""
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--only", "fig1_single_devise"])
    assert exc.value.code == 2
    captured = capsys.readouterr()
    assert "unknown module" in captured.err
    for name, _ in bench_run.MODULES:
        assert name in captured.err
    assert "name,us_per_call" not in captured.out


def test_known_only_name_is_accepted():
    # Argument validation only — pick a module and make sure parsing
    # passes (moe_dispatch is the cheapest real module, but any name in
    # MODULES must clear the check; we don't execute it here).
    names = [n for n, _ in bench_run.MODULES]
    assert "fig1_single_device" in names


def test_json_trajectory_from_tiny_fig1(tmp_path, monkeypatch):
    """The harness writes BENCH-style json: per-strategy us/call,
    voxel-updates/s, and the autotuner's chosen config — and appends on
    the next run instead of overwriting."""
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    monkeypatch.setattr(common, "TINY", True)
    path = tmp_path / "bench.json"

    bench_run.main(["--only", "fig1_single_device", "--json", str(path)])
    doc = json.loads(path.read_text())
    assert len(doc["runs"]) == 1
    run0 = doc["runs"][0]
    assert run0["meta"]["tiny"] is True
    assert run0["meta"]["failures"] == 0

    rows = {r["name"]: r for r in run0["rows"]}
    for strat in STRATEGIES + ("auto",):
        row = rows[f"fig1/{strat}"]
        assert row["us_per_call"] > 0
        assert row["fields"]["gups"] > 0          # voxel-updates/s

    tuned = run0["extras"]["tuned_config"]
    assert tuned["strategy"] in STRATEGIES
    assert rows["fig1/auto"]["fields"]["chosen"] == tuned["strategy"]
    assert len(tuned["timings"]) >= 5

    # Second run appends a trajectory entry with *fresh* rows (main()
    # resets the collection state, so nothing from run 1 replays).
    bench_run.main(["--only", "fig1_single_device", "--json", str(path)])
    doc = json.loads(path.read_text())
    assert len(doc["runs"]) == 2
    assert len(doc["runs"][1]["rows"]) == len(run0["rows"])
