"""Benchmark harness: --only validation and the --json perf trajectory."""

import json

import pytest

from benchmarks import common
from benchmarks import run as bench_run
from repro.core.backproject import STRATEGIES
from repro.tune import clear_memory_cache


def test_only_typo_lists_modules_and_exits_nonzero(capsys):
    """An unknown --only name must not print a lone CSV header and
    exit 0 (the old behaviour); it lists valid modules and fails."""
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--only", "fig1_single_devise"])
    assert exc.value.code == 2
    captured = capsys.readouterr()
    assert "unknown module" in captured.err
    for name, _ in bench_run.MODULES:
        assert name in captured.err
    assert "name,us_per_call" not in captured.out


def test_known_only_name_is_accepted():
    # Argument validation only — pick a module and make sure parsing
    # passes (moe_dispatch is the cheapest real module, but any name in
    # MODULES must clear the check; we don't execute it here).
    names = [n for n, _ in bench_run.MODULES]
    assert "fig1_single_device" in names
    assert "table5_traffic" in names


def test_only_comma_list_rejects_any_bad_name(capsys):
    """CI passes a comma-separated --only; one bad name fails the whole
    invocation with the module list, same as the single-name case."""
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--only", "fig1_single_device,not_a_module"])
    assert exc.value.code == 2
    assert "unknown module" in capsys.readouterr().err


def test_json_trajectory_from_tiny_fig1(tmp_path, monkeypatch):
    """The harness writes BENCH-style json: per-strategy us/call,
    voxel-updates/s, and the autotuner's chosen config — and appends on
    the next run instead of overwriting."""
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    monkeypatch.setattr(common, "TINY_ENV", True)
    path = tmp_path / "bench.json"

    bench_run.main(["--only", "fig1_single_device", "--json", str(path)])
    doc = json.loads(path.read_text())
    assert len(doc["runs"]) == 1
    run0 = doc["runs"][0]
    assert run0["meta"]["tiny"] is True
    assert run0["meta"]["failures"] == 0

    rows = {r["name"]: r for r in run0["rows"]}
    for strat in STRATEGIES + ("auto",):
        row = rows[f"fig1/{strat}"]
        assert row["us_per_call"] > 0
        assert row["fields"]["gups"] > 0          # voxel-updates/s

    tuned = run0["extras"]["tuned_config"]
    assert tuned["strategy"] in STRATEGIES
    assert rows["fig1/auto"]["fields"]["chosen"] == tuned["strategy"]
    assert len(tuned["timings"]) >= 5
    # The decision carries the pbatch axis and the current schema
    # version (acceptance: tuned config includes pbatch).
    assert tuned["opts"].get("pbatch", 0) >= 1
    from repro.tune import TUNE_SCHEMA_VERSION
    assert tuned["version"] == TUNE_SCHEMA_VERSION
    # The batched loop nest is benchmarked at several *effective*
    # depths (requested depths clamp to the tiny n_proj).
    batch_rows = [r for n, r in rows.items()
                  if n.startswith("fig1/batch/p")]
    assert len(batch_rows) >= 2
    assert all(r["us_per_call"] > 0 for r in batch_rows)
    assert any(r["fields"]["pbatch"] > 1 for r in batch_rows)

    # Second run appends a trajectory entry with *fresh* rows (main()
    # resets the collection state, so nothing from run 1 replays).
    bench_run.main(["--only", "fig1_single_device", "--json", str(path)])
    doc = json.loads(path.read_text())
    assert len(doc["runs"]) == 2
    assert len(doc["runs"][1]["rows"]) == len(run0["rows"])


def test_table5_traffic_models_pbatch_reduction(tmp_path, monkeypatch):
    """table5 commits the volume-traffic model: the chosen-pbatch row's
    modelled bytes are the sequential bytes divided by the chosen
    depth (acceptance criterion)."""
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    monkeypatch.setattr(common, "TINY_ENV", True)
    path = tmp_path / "bench.json"
    bench_run.main(["--only", "fig1_single_device,table5_traffic",
                    "--json", str(path)])
    run0 = json.loads(path.read_text())["runs"][0]
    assert run0["meta"]["failures"] == 0
    assert run0["meta"]["modules"] == ["fig1_single_device",
                                      "table5_traffic"]

    traffic = run0["extras"]["table5_traffic"]
    chosen = traffic["chosen_pbatch"]
    assert chosen >= 1
    from benchmarks.table5_traffic import volume_bytes

    L, n_proj = traffic["L"], traffic["n_proj"]
    assert traffic["volume_bytes_seq"] == volume_bytes(L, n_proj, 1)
    assert traffic["volume_bytes_chosen"] == volume_bytes(L, n_proj,
                                                          chosen)
    rows = {r["name"]: r for r in run0["rows"]}
    row = rows["table5/chosen"]
    assert row["fields"]["pbatch"] == chosen
    assert row["fields"]["vol_reduction"] == pytest.approx(
        traffic["volume_bytes_seq"] / traffic["volume_bytes_chosen"])


# ----------------------------------------------------------------------
# Regression gate (benchmarks/check_regression.py)
# ----------------------------------------------------------------------

def _traj(path, us_by_name, backend="cpu", device_kind="cpu", tiny=True):
    entry = {
        "timestamp": "2026-01-01T00:00:00Z",
        "meta": {"backend": backend, "device_kind": device_kind,
                 "tiny": tiny, "failures": 0, "modules": []},
        "rows": [{"name": n, "us_per_call": us, "derived": "",
                  "fields": {}} for n, us in us_by_name.items()],
        "extras": {},
    }
    import pathlib
    p = pathlib.Path(path)
    doc = {"runs": []}
    if p.is_file():
        doc = json.loads(p.read_text())
    doc["runs"].append(entry)
    p.write_text(json.dumps(doc))


def test_regression_gate_passes_within_threshold(tmp_path):
    from benchmarks import check_regression

    base, fresh = tmp_path / "base.json", tmp_path / "fresh.json"
    _traj(base, {"fig1/gather": 1000.0})
    _traj(fresh, {"fig1/gather": 2500.0})     # 2.5x < 4x: noise budget
    check_regression.main(["--baseline", str(base), "--fresh", str(fresh),
                           "--threshold", "4.0", "--min-us", "200"])


def test_regression_gate_fails_past_threshold(tmp_path, capsys):
    from benchmarks import check_regression

    base, fresh = tmp_path / "base.json", tmp_path / "fresh.json"
    _traj(base, {"fig1/gather": 1000.0, "fig1/strip2": 500.0})
    _traj(fresh, {"fig1/gather": 5000.0, "fig1/strip2": 600.0})
    with pytest.raises(SystemExit) as exc:
        check_regression.main(["--baseline", str(base), "--fresh",
                               str(fresh), "--threshold", "4.0",
                               "--min-us", "200"])
    assert exc.value.code == 1
    out = capsys.readouterr().out
    assert "REGRESSION fig1/gather" in out
    assert "fig1/strip2" not in out.replace("compared", "")


def test_regression_gate_skips_noise_rows_and_compares_latest(tmp_path):
    """µs-scale rows below --min-us never fail the gate, and the
    baseline is the *latest* committed entry for the identity."""
    from benchmarks import check_regression

    base, fresh = tmp_path / "base.json", tmp_path / "fresh.json"
    _traj(base, {"fig1/gather": 50.0})        # old slow entry
    _traj(base, {"fig1/gather": 10.0})        # latest entry: 10us
    _traj(fresh, {"fig1/gather": 1000.0})     # 100x but below min-us
    check_regression.main(["--baseline", str(base), "--fresh", str(fresh),
                           "--threshold", "4.0", "--min-us", "200"])


def test_regression_gate_noise_floor_is_symmetric(tmp_path, capsys):
    """Sub-floor medians are incomparable noise in *both* directions: a
    fresh row above the floor must never fail against a sub-floor
    baseline (the ratio is all baseline jitter), and a sub-floor fresh
    row against an above-floor baseline is skipped, not scored."""
    from benchmarks import check_regression

    base, fresh = tmp_path / "base.json", tmp_path / "fresh.json"
    _traj(base, {"fig1/a": 150.0,       # sub-floor baseline
                 "fig1/b": 1000.0})     # above-floor baseline
    _traj(fresh, {"fig1/a": 4000.0,     # 26x "regression" vs noise
                  "fig1/b": 80.0})      # sub-floor fresh
    check_regression.main(["--baseline", str(base), "--fresh", str(fresh),
                           "--threshold", "2.5", "--min-us", "200"])
    out = capsys.readouterr().out
    assert "no regressions" in out
    assert "compared 0 row(s)" in out


def test_regression_gate_vacuous_without_matching_identity(tmp_path,
                                                           capsys):
    from benchmarks import check_regression

    base, fresh = tmp_path / "base.json", tmp_path / "fresh.json"
    _traj(base, {"fig1/gather": 1000.0}, device_kind="TPU v5e")
    _traj(fresh, {"fig1/gather": 99999.0})
    check_regression.main(["--baseline", str(base), "--fresh", str(fresh)])
    assert "vacuously" in capsys.readouterr().out


def test_regression_gate_rejects_empty_fresh(tmp_path):
    from benchmarks import check_regression

    fresh = tmp_path / "fresh.json"
    with pytest.raises(SystemExit) as exc:
        check_regression.main(["--baseline", str(tmp_path / "b.json"),
                               "--fresh", str(fresh)])
    assert exc.value.code == 2


def test_regression_gate_fails_when_all_baseline_rows_dropped(tmp_path,
                                                              capsys):
    """A fresh run whose benchmark modules crashed emits no comparable
    rows; that used to sail through as 'no regressions'.  Zero rows
    compared with baseline rows expected = gate failure, and the missing
    rows are named."""
    from benchmarks import check_regression

    base, fresh = tmp_path / "base.json", tmp_path / "fresh.json"
    _traj(base, {"fig1/gather": 1000.0, "fig4/ttfv/b1": 5000.0})
    _traj(fresh, {"other/row": 10.0})     # module crashed: rows dropped
    with pytest.raises(SystemExit) as exc:
        check_regression.main(["--baseline", str(base), "--fresh",
                               str(fresh), "--min-us", "200"])
    assert exc.value.code == 1
    out = capsys.readouterr()
    assert "MISSING fig1/gather" in out.out
    assert "MISSING fig4/ttfv/b1" in out.out
    assert "zero rows compared" in out.err


def test_regression_gate_reports_partially_missing_rows(tmp_path, capsys):
    """Rows above --min-us that vanished are reported even when other
    rows still compare (and pass); noise-floor rows are not."""
    from benchmarks import check_regression

    base, fresh = tmp_path / "base.json", tmp_path / "fresh.json"
    _traj(base, {"fig1/gather": 1000.0, "fig4/ttfv/b1": 5000.0,
                 "fig1/tiny": 10.0})
    _traj(fresh, {"fig1/gather": 1100.0})
    check_regression.main(["--baseline", str(base), "--fresh", str(fresh),
                           "--min-us", "200"])
    out = capsys.readouterr().out
    assert "MISSING fig4/ttfv/b1" in out
    assert "fig1/tiny" not in out          # below the noise floor
    assert "no regressions" in out


def test_tiny_does_not_latch_across_inprocess_runs(monkeypatch):
    """--tiny must not leak into a later in-process main() without the
    flag (RESULTS/EXTRAS were reset; TINY silently stayed True)."""
    monkeypatch.setattr(common, "TINY", False)
    monkeypatch.setattr(common, "TINY_ENV", False)
    with pytest.raises(SystemExit):
        bench_run.main(["--tiny", "--only", "nonexistent_module"])
    assert common.TINY is False            # parse failed before assign
    bench_run.main(["--tiny", "--only", "moe_dispatch"])
    assert common.TINY is True
    bench_run.main(["--only", "moe_dispatch"])
    assert common.TINY is False            # assigned, not latched
    # The REPRO_BENCH_TINY env opt-in survives the per-run assignment.
    monkeypatch.setattr(common, "TINY_ENV", True)
    bench_run.main(["--only", "moe_dispatch"])
    assert common.TINY is True
