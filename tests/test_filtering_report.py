"""FDK filtering properties + roofline report rendering."""

import dataclasses
import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Geometry
from repro.core.filtering import (cosine_weights, filter_projections,
                                  parker_weights, ramlak_kernel)


def test_ramp_kills_dc():
    """The ramp filter has zero DC response: a constant projection
    filters to ~0 away from the linear-convolution boundary."""
    geom = Geometry().scaled(16)
    const = np.ones((1, geom.n_v, geom.n_u), np.float32)
    out = np.asarray(filter_projections(const, geom, short_scan=False))
    noise = np.random.default_rng(0).normal(
        size=(1, geom.n_v, geom.n_u)).astype(np.float32)
    outn = np.asarray(filter_projections(noise, geom, short_scan=False))
    interior = np.abs(out[0, :, 4:-4]).max()
    assert interior < 0.05 * np.abs(outn).max()


def test_ramlak_kernel_structure():
    h = ramlak_kernel(16, du=1.0)
    k = np.arange(-8, 8)
    assert h[k == 0] == 0.25
    assert (h[(np.abs(k) % 2 == 0) & (k != 0)] == 0).all()
    assert (h[np.abs(k) % 2 == 1] < 0).all()


def test_cosine_weights_bounded_and_centered():
    geom = Geometry().scaled(16)
    w = cosine_weights(geom)
    assert w.max() <= 1.0 + 1e-6
    iv, iu = np.unravel_index(np.argmax(w), w.shape)
    assert abs(iu - geom.cu) <= 1 and abs(iv - geom.cv) <= 1


def test_parker_weights_full_scan_constant():
    geom = dataclasses.replace(Geometry().scaled(16), sweep=2 * math.pi)
    pw = parker_weights(geom)
    assert np.allclose(pw, 1.0)


def test_parker_weights_short_scan_shape():
    geom = Geometry().scaled(16)         # 200-degree C-arm
    pw = parker_weights(geom)
    assert pw.shape == (geom.n_proj, geom.n_u)
    assert pw.min() >= 0.0
    # Ramp-up at the start of the sweep: first projection nearly zero.
    assert pw[0].max() < 0.2
    # Plateau in the middle of the sweep near the constant-2 level
    # (the factor-2 compensates the retained FDK 1/2 — filtering.py).
    assert abs(pw[geom.n_proj // 2].mean() - 2.0) < 0.2


def test_nonprefix_subset_matches_full_stack_rows():
    """The filtering-contract fix: a shuffled, non-prefix subset with
    explicit angle_indices filters identically to the matching rows of
    the full-stack result.  (The old code silently applied the *first k*
    angles' Parker weights to any k-subset — wrong for every non-prefix
    subset a streamed or proj-sharded caller sends.)"""
    geom = Geometry().scaled(16, n_proj=8)
    rng = np.random.default_rng(3)
    projs = rng.normal(size=(8, geom.n_v, geom.n_u)).astype(np.float32)
    full = np.asarray(filter_projections(projs, geom))
    idx = np.array([6, 2, 5])                    # shuffled, non-prefix
    sub = np.asarray(filter_projections(projs[idx], geom,
                                        angle_indices=idx))
    np.testing.assert_array_equal(sub, full[idx])
    # And the old prefix guess is demonstrably NOT those rows (Parker
    # ramp-up weights at angles 0..2 differ from angles 6/2/5).
    prefix = np.asarray(filter_projections(projs[idx], geom,
                                           angle_indices=np.arange(3)))
    assert np.abs(prefix - sub).max() > 1e-3


def test_mismatched_subset_without_indices_raises():
    """A short-scan subset must say which angles it holds — guessing is
    the silent mis-weighting bug."""
    geom = Geometry().scaled(16, n_proj=8)
    projs = np.ones((3, geom.n_v, geom.n_u), np.float32)
    with pytest.raises(ValueError, match="angle_indices"):
        filter_projections(projs, geom)
    # Explicitly opting out of Parker weighting still works.
    out = filter_projections(projs, geom, short_scan=False)
    assert out.shape == projs.shape
    # And a full-length stack keeps the no-indices convenience path.
    full = np.ones((8, geom.n_v, geom.n_u), np.float32)
    assert filter_projections(full, geom).shape == full.shape


def test_single_projection_scalar_angle_index():
    geom = Geometry().scaled(16, n_proj=8)
    projs = np.random.default_rng(0).normal(
        size=(8, geom.n_v, geom.n_u)).astype(np.float32)
    full = np.asarray(filter_projections(projs, geom))
    one = np.asarray(filter_projections(projs[5], geom, angle_indices=5))
    assert one.shape == (geom.n_v, geom.n_u)
    np.testing.assert_array_equal(one, full[5])
    with pytest.raises(ValueError, match=r"\[0, 8\)"):
        filter_projections(projs[5], geom, angle_indices=9)
    with pytest.raises(ValueError, match="shape"):
        filter_projections(projs[:2], geom, angle_indices=np.arange(3))


def test_report_renders(tmp_path):
    from repro.analysis.report import load, roofline_table, summary
    rec = {
        "arch": "test-arch", "shape": "train_4k", "mesh": "pod",
        "chips": 256, "status": "ok", "step": "train_step",
        "model_params": 1, "active_params": 1,
        "roofline": {"compute_s": 1.0, "memory_s": 2.0,
                     "collective_s": 0.5, "dominant": "memory",
                     "bound_s": 2.0},
        "useful_flops_ratio": 0.5,
        "memory": {"live_bytes": 8e9},
        "fits_16gb_hbm": True,
    }
    skip = {"arch": "test-arch", "shape": "long_500k", "mesh": "pod",
            "status": "skipped", "reason": "sub-quadratic required"}
    for i, r in enumerate((rec, skip)):
        with open(tmp_path / f"r{i}.json", "w") as f:
            json.dump(r, f)
    recs = load(str(tmp_path))
    assert "1 ok, 1 skipped" in summary(recs)
    table = roofline_table(recs, "pod")
    assert "test-arch" in table and "memory" in table
    assert "50.0%" in table           # MFU-bound = compute/bound
    assert "skip" in table
