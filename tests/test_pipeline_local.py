"""sharded_reconstruct on a trivial 1x1 mesh == single-device reconstruct.

Multi-device CI is not assumed: this exercises the full shard_map path
(mesh plumbing, logical-axis spec resolution, the psum over projection
axes, and the ``shard_constraint`` on the output) on one device, where
the decomposition must be *bit-for-bit* the single-device computation —
one z-slab covering the whole volume, one projection subset covering all
projections, and a size-1 psum.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import Geometry, filter_projections, reconstruct
from repro.core.phantom import make_dataset
from repro.core.pipeline import sharded_reconstruct
from repro.launch.mesh import make_local_mesh


def test_sharded_reconstruct_identity_mesh_bitwise():
    geom = Geometry().scaled(16, n_proj=4)
    projs, mats, _ = make_dataset(geom)
    filt = np.asarray(filter_projections(projs, geom))
    mesh = make_local_mesh(data=1, model=1)
    out = np.asarray(sharded_reconstruct(filt, mats, geom, mesh,
                                         strategy="gather"))
    single = np.asarray(reconstruct(filt, mats, geom, strategy="gather"))
    assert out.sum() != 0.0
    np.testing.assert_array_equal(out, single)


def test_sharded_reconstruct_identity_mesh_bitwise_strip2():
    """Same bit-for-bit claim for the default (strip2) strategy."""
    geom = Geometry().scaled(16, n_proj=2)
    projs, mats, _ = make_dataset(geom)
    filt = np.asarray(filter_projections(projs, geom))
    mesh = make_local_mesh(data=1, model=1)
    out = np.asarray(sharded_reconstruct(filt, mats, geom, mesh))
    single = np.asarray(reconstruct(filt, mats, geom))
    np.testing.assert_array_equal(out, single)
