"""sharded_reconstruct on a trivial 1x1 mesh == single-device reconstruct.

Multi-device CI is not assumed: this exercises the full shard_map path
(mesh plumbing, logical-axis spec resolution, the psum over projection
axes, and the ``shard_constraint`` on the output) on one device, where
the decomposition must be *bit-for-bit* the single-device computation —
one z-slab covering the whole volume, one projection subset covering all
projections, and a size-1 psum.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import Geometry, filter_projections, reconstruct
from repro.core.phantom import make_dataset
from repro.core.pipeline import sharded_reconstruct
from repro.launch.mesh import make_local_mesh


def test_sharded_reconstruct_identity_mesh_bitwise():
    geom = Geometry().scaled(16, n_proj=4)
    projs, mats, _ = make_dataset(geom)
    filt = np.asarray(filter_projections(projs, geom))
    mesh = make_local_mesh(data=1, model=1)
    out = np.asarray(sharded_reconstruct(filt, mats, geom, mesh,
                                         strategy="gather"))
    single = np.asarray(reconstruct(filt, mats, geom, strategy="gather"))
    assert out.sum() != 0.0
    np.testing.assert_array_equal(out, single)


def test_sharded_reconstruct_identity_mesh_bitwise_strip2():
    """Same bit-for-bit claim for the default (strip2) strategy."""
    geom = Geometry().scaled(16, n_proj=2)
    projs, mats, _ = make_dataset(geom)
    filt = np.asarray(filter_projections(projs, geom))
    mesh = make_local_mesh(data=1, model=1)
    out = np.asarray(sharded_reconstruct(filt, mats, geom, mesh))
    single = np.asarray(reconstruct(filt, mats, geom))
    np.testing.assert_array_equal(out, single)


def test_sharded_prefiltered_false_filters_in_shard_bitwise():
    """prefiltered=False: the raw stack is FDK-filtered *inside* the
    shard_map body with angle-indexed Parker rows; on a 1x1 mesh the
    result is bit-for-bit filter_projections + reconstruct."""
    geom = Geometry().scaled(16, n_proj=4)
    projs, mats, _ = make_dataset(geom)
    mesh = make_local_mesh(data=1, model=1)
    out = np.asarray(sharded_reconstruct(projs, mats, geom, mesh,
                                         prefiltered=False))
    filt = np.asarray(filter_projections(projs, geom))
    single = np.asarray(reconstruct(filt, mats, geom))
    assert out.sum() != 0.0
    np.testing.assert_array_equal(out, single)


def test_sharded_prefiltered_false_rejects_subset():
    """The raw path filters by global angle index, so it must see the
    full scan — a subset cannot be weighted correctly here."""
    import pytest

    geom = Geometry().scaled(16, n_proj=4)
    projs, mats, _ = make_dataset(geom)
    mesh = make_local_mesh(data=1, model=1)
    with pytest.raises(ValueError, match="full scan"):
        sharded_reconstruct(projs[:2], mats[:2], geom, mesh,
                            prefiltered=False)


def test_reconstruct_shards_z0_slab_offset():
    """The exported per-rank body back-projects a *non-first* z-slab
    correctly when handed its global offset (it used to hard-code
    z0=0, silently reconstructing the wrong planes)."""
    import jax.numpy as jnp

    from repro.core.backproject import GeomStatic
    from repro.core.pipeline import reconstruct_shards
    from repro.dispatch import ExecutionPlan

    geom = Geometry().scaled(16, n_proj=2)
    projs, mats, _ = make_dataset(geom)
    filt = np.asarray(filter_projections(projs, geom))
    full = np.asarray(reconstruct(filt, mats, geom))
    gs = GeomStatic.of(geom)
    half = geom.L // 2
    plan = ExecutionPlan.explicit("strip2")
    lo = reconstruct_shards(filt, mats, gs, plan,
                            jnp.zeros((half,) + (geom.L,) * 2,
                                      jnp.float32))
    hi = reconstruct_shards(filt, mats, gs, plan,
                            jnp.zeros((half,) + (geom.L,) * 2,
                                      jnp.float32), z0=half)
    np.testing.assert_array_equal(np.asarray(lo), full[:half])
    np.testing.assert_array_equal(np.asarray(hi), full[half:])
    # The old behaviour (default z0) is NOT the upper slab.
    assert np.abs(np.asarray(lo) - full[half:]).max() > 0
