"""Launcher + dry-run machinery unit tests (no 512-device init here)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES
from repro.configs.registry import cell_supported, cells
from repro.dist.sharding import (ShardingRules, logical_to_spec,
                                 valid_spec)


class FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


def test_cells_inventory():
    all_cells = cells()
    assert len(all_cells) == 40
    skips = [c for c in all_cells if not c[2]]
    assert len(skips) == 8            # long_500k for full-attention archs
    for cfg, shape, ok, why in skips:
        assert shape.name == "long_500k"
        assert cfg.family not in ("ssm", "hybrid")
        assert "sub-quadratic" in why


def test_long500k_runs_for_ssm_hybrid():
    for name in ("xlstm-125m", "jamba-v0.1-52b"):
        ok, _ = cell_supported(ARCHS[name], SHAPES["long_500k"])
        assert ok


def test_logical_to_spec_prunes_missing_axes():
    rules = ShardingRules()
    spec = logical_to_spec(("batch", None, "tp"), rules, FakeMesh())
    assert spec == P(("pod", "data"), None, "model")

    class PodlessMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    spec = logical_to_spec(("batch", None, "tp"), rules, PodlessMesh())
    assert spec == P("data", None, "model")


def test_valid_spec_drops_indivisible():
    spec = valid_spec((768, 8), P("data", "model"), FakeMesh())
    assert spec == P("data")          # 8 % 16 != 0 -> replicated dim
    spec = valid_spec((32, 32), P(("pod", "data"), "model"), FakeMesh())
    assert spec == P(("pod", "data"), "model")
    spec = valid_spec((33, 32), P(("pod", "data"), "model"), FakeMesh())
    assert spec == P(None, "model")


def test_input_specs_shapes():
    from repro.launch.dryrun import input_specs
    cfg = ARCHS["chatglm3-6b"]
    b = input_specs(cfg, SHAPES["train_4k"])
    assert b["tokens"].shape == (256, 4096)
    assert b["labels"].shape == (256, 4096)
    b = input_specs(cfg, SHAPES["decode_32k"])
    assert b["tokens"].shape == (128, 1)
    vl = input_specs(ARCHS["qwen2-vl-2b"], SHAPES["train_4k"])
    assert vl["patches"].shape[1] + vl["tokens"].shape[1] == 4096
    wh = input_specs(ARCHS["whisper-small"], SHAPES["prefill_32k"])
    assert wh["frames"].shape == (32, 32768, 80)


def test_param_count_sanity():
    """Configs land near their nameplate sizes."""
    approx = {
        "chatglm3-6b": (5e9, 8e9),
        "internlm2-20b": (17e9, 24e9),
        "mistral-nemo-12b": (10e9, 14e9),
        "qwen3-moe-235b-a22b": (2.0e11, 2.7e11),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "xlstm-125m": (0.8e8, 2.2e8),
    }
    for name, (lo, hi) in approx.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n / 1e9:.1f}B not in range"
    # Active params well below total for the MoE giants.
    for name in ("qwen3-moe-235b-a22b", "kimi-k2-1t-a32b"):
        cfg = ARCHS[name]
        assert cfg.active_param_count() < 0.25 * cfg.param_count()


def test_train_launcher_smoke(tmp_path):
    """The production launcher end to end on the local mesh."""
    import subprocess
    import sys
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "chatglm3-6b", "--reduced", "--steps", "6", "--seq", "16",
         "--batch", "2", "--save-every", "3",
         "--ckpt", str(tmp_path / "ck")],
        env=env, capture_output=True, text=True, timeout=560, cwd=root)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "finished at step 6" in out.stdout
    from repro.ckpt.checkpoint import all_steps
    assert all_steps(str(tmp_path / "ck"))
