"""Manual-EP MoE vs portable scatter on a multi-device mesh (subprocess)."""

import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_manual_ep_matches_scatter_on_mesh():
    script = textwrap.dedent(f"""
        import os, json
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8")
        import sys; sys.path.insert(0, {os.path.join(ROOT, 'src')!r})
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs.base import ModelConfig
        from repro.dist.sharding import ShardingRules, sharding_context
        from repro.launch.mesh import make_local_mesh
        from repro.models.layers import Param
        from repro.models.moe import init_moe, moe_forward

        cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=0, vocab=64,
                          moe=True, n_experts=8, top_k=2, moe_d_ff=16,
                          capacity_factor=8.0, param_dtype="float32")
        p = Param(jax.random.PRNGKey(0), jnp.float32)
        init_moe(p, cfg)
        params = p.params
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        mesh = make_local_mesh(data=2, model=4)
        rules = ShardingRules(batch=("data",), fsdp=(), tp=("model",),
                              ep=("model",))
        ref, aux_ref = moe_forward(params, cfg, x, impl="scatter",
                                   dtype=jnp.float32)
        with sharding_context(mesh, rules):
            out, aux = jax.jit(lambda pp, xx: moe_forward(
                pp, cfg, xx, impl="ep", dtype=jnp.float32))(params, x)
        print(json.dumps({{
            "diff": float(jnp.abs(out - ref).max()),
            "aux_diff": abs(float(aux) - float(aux_ref)),
            "scale": float(jnp.abs(ref).max())}}))
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=560,
                         cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # Dropless regime: manual EP output must agree with the portable
    # path exactly.  The aux loss is *group-local* under EP (mean of
    # per-shard f*P products, like GShard groups) — same scale, not
    # bitwise equal.
    assert rec["diff"] < 1e-4 * max(rec["scale"], 1.0), rec
    assert rec["aux_diff"] < 0.2, rec
