"""Multi-tenant front door: admission policies, backpressure, abort.

Three layers of claim (DESIGN.md §14):

* **Policy properties** — the admission policies are pure functions of
  (pending, context), so their scheduling guarantees hold as properties:
  SRSF's linear aging bounds starvation, the deadline policy is exactly
  least-slack order, fair share always serves the least-loaded tenant.
* **Tier contracts** — a full house raises :class:`Backpressure` with a
  positive ``retry_after``; over-declared submission is loud; a
  cancelled ticket raises :class:`ScanAborted`; abort-then-reuse of a
  slot is bit-clean (the next scan through that slot matches the
  oracle to the same tolerance as a fresh engine).
* **End to end** — N clients interleaving chunk streams through one
  event loop all converge to the one-shot ``reconstruct`` volume, under
  every policy, and the sharded backend on the trivial 1x1 mesh matches
  bitwise-close too.
"""

import asyncio

import numpy as np
import pytest

from _prop import given, settings, st
from repro.api import (Backpressure, CTFrontDoor, DeadlinePolicy,
                       FairSharePolicy, FIFOPolicy, Geometry,
                       PolicyContext, ProjectionChunk, ScanAborted,
                       SRSFPolicy, filter_projections, reconstruct)
from repro.core.phantom import make_dataset
from repro.serving.ct_frontdoor import POLICIES, ScanTicket, _resolve_policy

GEOM = Geometry().scaled(16, n_proj=6)
_DS = make_dataset(GEOM)


def _oracle():
    projs, mats, _ = _DS
    filt = np.asarray(filter_projections(projs, GEOM))
    return np.asarray(reconstruct(filt, mats, GEOM))


REF = _oracle()


def _ticket(tid, *, n_proj=8, tenant="default", arrived=0.0,
            deadline=None):
    return ScanTicket(tid=tid, tenant=tenant, n_proj=n_proj,
                      deadline=deadline, arrived=arrived)


def _ctx(now=0.0, active=None, admitted=None, est_proj_s=0.0):
    return PolicyContext(now=now, active=active or {},
                         admitted=admitted or {}, est_proj_s=est_proj_s)


async def _stream(fd, projs, mats, *, chunk=3, tenant="default"):
    ticket = await fd.open_scan(tenant=tenant, n_proj=GEOM.n_proj)
    order = np.arange(GEOM.n_proj)
    for c0 in range(0, GEOM.n_proj, chunk):
        idx = order[c0:c0 + chunk]
        await fd.submit(ticket, ProjectionChunk(projs[idx], mats[idx],
                                                idx))
    return np.asarray(await fd.result(ticket))


# ----------------------------------------------------------------------
# Policy properties
# ----------------------------------------------------------------------

@given(long=st.integers(10, 500), wait=st.floats(0.0, 1000.0),
       aging=st.floats(0.1, 10.0))
@settings(max_examples=40, deadline=None)
def test_srsf_aging_bounds_starvation(long, wait, aging):
    """A scan that has waited past ``(its remaining - shortest
    remaining) / aging`` seconds outranks every fresh short arrival —
    SRSF with aging > 0 cannot starve it indefinitely."""
    short = 5
    pending = (_ticket(0, n_proj=long, arrived=-wait),
               _ticket(1, n_proj=short, arrived=0.0))
    pick = SRSFPolicy(aging=aging).select(pending, _ctx(now=0.0))
    aged_key = long - aging * wait          # the policy's own key
    if aged_key <= short:                   # waited past the bound
        assert pick == 0                    # (ties keep arrival order)
    else:
        assert pick == 1            # fresh short scan still preferred


def test_srsf_without_wait_is_shortest_first():
    pending = (_ticket(0, n_proj=50), _ticket(1, n_proj=3),
               _ticket(2, n_proj=20))
    assert SRSFPolicy().select(pending, _ctx()) == 1


@given(d0=st.floats(1.0, 100.0), d1=st.floats(1.0, 100.0),
       n0=st.integers(1, 200), n1=st.integers(1, 200),
       rate=st.floats(0.0, 0.5))
@settings(max_examples=40, deadline=None)
def test_deadline_policy_is_least_slack_order(d0, d1, n0, n1, rate):
    """The pick always has minimal slack = deadline - now - work left
    at the measured rate; a no-deadline ticket never beats one with a
    deadline."""
    pending = (_ticket(0, n_proj=n0, deadline=d0),
               _ticket(1, n_proj=n1, deadline=d1),
               _ticket(2, n_proj=1, deadline=None))
    ctx = _ctx(now=0.0, est_proj_s=rate)
    pick = DeadlinePolicy().select(pending, ctx)
    slack = [d0 - n0 * rate, d1 - n1 * rate, float("inf")]
    assert pick != 2
    assert slack[pick] == min(slack)


def test_fair_share_serves_least_loaded_tenant():
    pending = (_ticket(0, tenant="hog"), _ticket(1, tenant="hog"),
               _ticket(2, tenant="quiet"))
    ctx = _ctx(active={"hog": 2}, admitted={"hog": 7, "quiet": 1})
    assert FairSharePolicy().select(pending, ctx) == 2
    # All else equal, total admissions break the tie.
    ctx = _ctx(active={}, admitted={"hog": 7, "quiet": 1})
    assert FairSharePolicy().select(pending, ctx) == 2


def test_every_policy_is_fifo_among_equals():
    """Identical tickets: min keeps the first minimum, so every policy
    degrades to arrival order."""
    pending = tuple(_ticket(i) for i in range(4))
    for name, cls in POLICIES.items():
        assert cls().select(pending, _ctx()) == 0, name


def test_policy_resolution():
    assert isinstance(_resolve_policy("FIFO"), FIFOPolicy)
    p = SRSFPolicy(aging=2.0)
    assert _resolve_policy(p) is p
    with pytest.raises(ValueError, match="unknown admission policy"):
        _resolve_policy("lifo")
    with pytest.raises(TypeError):
        _resolve_policy(42)
    with pytest.raises(ValueError, match="aging"):
        SRSFPolicy(aging=-1.0)


# ----------------------------------------------------------------------
# Tier contracts: backpressure, bounds, cancellation, slot hygiene
# ----------------------------------------------------------------------

def test_full_house_raises_backpressure_with_hint():
    projs, mats, _ = _DS

    async def scenario():
        fd = CTFrontDoor(GEOM, n_slots=1, max_pending=2, pbatch=4)
        # 1 active + 2 pending = full house; the 4th arrival bounces.
        for _ in range(3):
            await fd.open_scan(n_proj=GEOM.n_proj)
        assert fd.active == 1 and fd.pending == 2
        with pytest.raises(Backpressure) as ei:
            await fd.open_scan(n_proj=GEOM.n_proj)
        assert ei.value.retry_after > 0
        assert fd.stats["rejected"] == 1
        # An explicit retry_after override is honoured verbatim.
        fd2 = CTFrontDoor(GEOM, n_slots=1, max_pending=1,
                          retry_after=7.5, pbatch=4)
        await fd2.open_scan()
        await fd2.open_scan()
        with pytest.raises(Backpressure) as ei:
            await fd2.open_scan()
        assert ei.value.retry_after == 7.5

    asyncio.run(scenario())


def test_over_declared_submission_is_loud():
    projs, mats, _ = _DS

    async def scenario():
        fd = CTFrontDoor(GEOM, n_slots=1, pbatch=4)
        ticket = await fd.open_scan(n_proj=4)
        idx = np.arange(3)
        await fd.submit(ticket, ProjectionChunk(projs[idx], mats[idx],
                                                idx))
        with pytest.raises(ValueError, match="declared 4"):
            await fd.submit(ticket, ProjectionChunk(projs[3:5], mats[3:5],
                                                    np.arange(3, 5)))
        with pytest.raises(TypeError, match="ProjectionChunk"):
            await fd.submit(ticket, projs[:1])

    asyncio.run(scenario())


def test_cancel_pending_and_active_raises_scan_aborted():
    projs, mats, _ = _DS

    async def scenario():
        fd = CTFrontDoor(GEOM, n_slots=1, max_pending=4, pbatch=4)
        active = await fd.open_scan(n_proj=GEOM.n_proj)
        queued = await fd.open_scan(n_proj=GEOM.n_proj)
        assert active.state == "active" and queued.state == "pending"
        assert await fd.cancel(queued)
        with pytest.raises(ScanAborted):
            await fd.result(queued)
        idx = np.arange(2)
        await fd.submit(active, ProjectionChunk(projs[idx], mats[idx],
                                                idx))
        assert await fd.cancel(active)
        with pytest.raises(ScanAborted):
            await fd.result(active)
        assert not await fd.cancel(active)      # already settled
        assert fd.stats["cancelled"] == 2
        # Settled tickets refuse further chunks.
        with pytest.raises(ValueError, match="aborted"):
            await fd.submit(active, ProjectionChunk(projs[idx],
                                                    mats[idx], idx))
        return fd

    fd = asyncio.run(scenario())
    assert fd.active == 0 and fd.pending == 0
    assert fd.free_slots == 1                   # the slot came back


def test_abort_then_reuse_is_bit_clean():
    """A half-streamed scan aborted mid-flight leaves no residue: the
    next scan through the freed slot matches the oracle exactly as a
    fresh engine would."""
    projs, mats, _ = _DS

    async def scenario():
        fd = CTFrontDoor(GEOM, n_slots=1, pbatch=4)
        poisoned = await fd.open_scan(n_proj=GEOM.n_proj)
        idx = np.arange(4)
        await fd.submit(poisoned, ProjectionChunk(projs[idx] * 1e3,
                                                  mats[idx], idx))
        await fd.cancel(poisoned)
        return await _stream(fd, projs, mats)

    out = asyncio.run(scenario())
    np.testing.assert_allclose(out, REF, atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------------------
# End to end
# ----------------------------------------------------------------------

@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_interleaved_clients_converge_under_every_policy(policy):
    projs, mats, _ = _DS

    async def scenario():
        fd = CTFrontDoor(GEOM, n_slots=2, max_pending=8, policy=policy,
                         pbatch=4)
        outs = await asyncio.gather(*(
            _stream(fd, projs, mats, chunk=c, tenant=t)
            for c, t in ((2, "a"), (3, "b"), (6, "a"), (1, "c"))))
        return outs, fd.stats

    outs, stats = asyncio.run(scenario())
    assert stats["completed"] == 4
    for out in outs:
        np.testing.assert_allclose(out, REF, atol=1e-5, rtol=1e-5)


def test_deadline_policy_admits_tightest_slo_first():
    """With one slot busy and three queued, the freed slot goes to the
    ticket whose deadline is soonest — not the first arrival."""
    projs, mats, _ = _DS

    async def scenario():
        fd = CTFrontDoor(GEOM, n_slots=1, max_pending=8,
                         policy="deadline", pbatch=4)
        blocker = await fd.open_scan(n_proj=GEOM.n_proj)
        loose = await fd.open_scan(n_proj=GEOM.n_proj, deadline=1e9)
        tight = await fd.open_scan(n_proj=GEOM.n_proj, deadline=1.0)
        none = await fd.open_scan(n_proj=GEOM.n_proj)
        await fd.cancel(blocker)                # frees the slot
        assert tight.state == "active"
        assert loose.state == "pending" and none.state == "pending"

    asyncio.run(scenario())


def test_sharded_backend_identity_mesh_matches_oracle():
    from repro.launch.mesh import make_local_mesh

    projs, mats, _ = _DS
    mesh = make_local_mesh(data=1, model=1)

    async def scenario():
        fd = CTFrontDoor(GEOM, mesh=mesh, n_slots=1, pbatch=4)
        # Sharded mode requires full scans: a partial declaration fails
        # at open_scan, in the caller, not mid-pump.
        with pytest.raises(ValueError, match="must be full"):
            await fd.open_scan(n_proj=3)
        ticket = await fd.open_scan(n_proj=GEOM.n_proj)
        order = np.random.default_rng(3).permutation(GEOM.n_proj)
        for c0 in range(0, GEOM.n_proj, 2):
            idx = order[c0:c0 + 2]
            await fd.submit(ticket, ProjectionChunk(projs[idx],
                                                    mats[idx], idx))
        return np.asarray(await fd.result(ticket))

    out = asyncio.run(scenario())
    np.testing.assert_allclose(out, REF, atol=1e-5, rtol=1e-5)


def test_sharded_backend_rejects_duplicate_angles():
    from repro.launch.mesh import make_local_mesh

    projs, mats, _ = _DS
    mesh = make_local_mesh(data=1, model=1)

    async def scenario():
        fd = CTFrontDoor(GEOM, mesh=mesh, n_slots=1)
        ticket = await fd.open_scan()
        idx = np.arange(3)
        await fd.submit(ticket, ProjectionChunk(projs[idx], mats[idx],
                                                idx))
        with pytest.raises(ValueError, match="exactly once"):
            await fd.submit(ticket, ProjectionChunk(projs[idx],
                                                    mats[idx], idx))

    asyncio.run(scenario())
