"""Cross-strategy equivalence sweep incl. out-of-detector border rays.

All five ``STRATEGIES`` implement one semantics: floor bilinear, zero
outside the detector, ``1/w^2`` weighting.  This sweep pins the border
behaviour specifically: the geometry below shrinks the detector so the
volume over-projects its edges, making every strategy exercise the
zero-padding path (the paper's §5.1.1 "zero-padded buffer beats mask
registers" trick) — taps straddling the detector edge must blend a real
pixel with an implicit zero, not clamp or extrapolate.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Geometry
from repro.core.backproject import (GeomStatic, STRATEGIES, _pad_image,
                                    _sample, backproject_one, plane_coords,
                                    sample_scalar)
from repro.core.geometry import projection_matrix

# Detector deliberately smaller than the volume footprint: corner voxels
# project outside it at every angle.
GEOM = Geometry().scaled(16, n_proj=8, n_u=24, n_v=18)
GS = GeomStatic.of(GEOM)

OPTS = {
    "scalar": {},
    "gather": {},
    "onehot": {"vox_block": 64},
    "strip": {"chunk": 8, "band": 16, "width": 128},
    "strip2": {"group": 8, "gband": 8, "gwidth": 64},
}


def _rand_case(seed):
    rng = np.random.default_rng(seed)
    theta = float(rng.uniform(0.0, 2.0 * np.pi))
    z = int(rng.integers(0, GEOM.L))
    image = jnp.asarray(rng.standard_normal((GEOM.n_v, GEOM.n_u)),
                        jnp.float32)
    A = jnp.asarray(projection_matrix(GEOM, theta), jnp.float32)
    return theta, z, image, A


def test_sweep_geometry_has_border_rays():
    """Sanity: the sweep actually crosses the detector border both ways."""
    n_in = n_out = 0
    for seed in range(8):
        _, z, _, A = _rand_case(seed)
        ix, iy, _ = plane_coords(A, GS, jnp.int32(z))
        inside = ((np.asarray(ix) >= 0) & (np.asarray(ix) < GEOM.n_u - 1)
                  & (np.asarray(iy) >= 0) & (np.asarray(iy) < GEOM.n_v - 1))
        n_in += int(inside.sum())
        n_out += int((~inside).sum())
    assert n_in > 0 and n_out > 0, (n_in, n_out)


@pytest.mark.parametrize("strategy",
                         [s for s in STRATEGIES if s != "scalar"])
@pytest.mark.parametrize("seed", range(6))
def test_sample_matches_scalar_oracle(strategy, seed):
    """Per-plane values agree with the scalar oracle to 1e-5."""
    _, z, image, A = _rand_case(seed)
    ix, iy, _ = plane_coords(A, GS, jnp.int32(z))
    ref = np.asarray(sample_scalar(image, ix, iy, GS))
    out = np.asarray(_sample(strategy, image, _pad_image(image), ix, iy,
                             GS, OPTS[strategy]))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("strategy",
                         [s for s in STRATEGIES if s != "scalar"])
def test_backproject_matches_scalar_oracle(strategy):
    """Whole-volume accumulation agrees across the border geometry."""
    rng = np.random.default_rng(42)
    image = jnp.asarray(rng.standard_normal((GEOM.n_v, GEOM.n_u)),
                        jnp.float32)
    A = jnp.asarray(projection_matrix(GEOM, 1.1), jnp.float32)
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    ref = np.asarray(backproject_one(vol0, image, A, GEOM,
                                     strategy="scalar"))
    out = np.asarray(backproject_one(vol0, image, A, GEOM,
                                     strategy=strategy, **OPTS[strategy]))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    # Border geometry must leave genuinely zero (out-of-detector) voxels
    # *and* nonzero ones, or the case proves nothing.
    assert (ref == 0.0).any() and (ref != 0.0).any()


def test_wide_footprint_windows_are_loud_or_correct():
    """Adversarial tap-loss hazard: at L=48 the per-chunk footprint
    outgrows small strip windows.  ``reconstruct`` must either produce
    the correct result (windows large enough) or raise loudly — never
    silently drop taps (gband=4 used to do exactly that)."""
    from repro.core import reconstruct
    from repro.core.geometry import projection_matrices

    geom = Geometry().scaled(48, n_proj=4)
    rng = np.random.default_rng(7)
    imgs = rng.standard_normal(
        (geom.n_proj, geom.n_v, geom.n_u)).astype(np.float32)
    mats = projection_matrices(geom)

    # Undersized windows: loud planner-backed error, not silent wrong.
    with pytest.raises(ValueError, match="does not cover"):
        reconstruct(imgs, mats, geom, strategy="strip2", gband=4)
    with pytest.raises(ValueError, match="does not cover"):
        reconstruct(imgs, mats, geom, strategy="strip", band=4)

    # Default windows validate and match the scalar oracle.
    ref = np.asarray(reconstruct(imgs, mats, geom, strategy="scalar"))
    for strategy in ("strip", "strip2"):
        out = np.asarray(reconstruct(imgs, mats, geom, strategy=strategy))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_full_window_is_satisfiable_on_tiny_detector():
    """The planner margin can push the raw requirement past the padded
    image itself (width 15 > n_u+2 = 14 on this geometry) — but a
    full-detector window clamps its origin to 0 and covers everything,
    so validation must accept it and the result must stay exact."""
    from repro.core import reconstruct
    from repro.core.geometry import projection_matrices

    geom = Geometry().scaled(16, n_proj=4, n_u=12, n_v=8)
    rng = np.random.default_rng(5)
    imgs = rng.standard_normal(
        (geom.n_proj, geom.n_v, geom.n_u)).astype(np.float32)
    mats = projection_matrices(geom)
    ref = np.asarray(reconstruct(imgs, mats, geom, strategy="scalar"))
    out = np.asarray(reconstruct(imgs, mats, geom, strategy="strip",
                                 chunk=16, band=64, width=64))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    out2 = np.asarray(reconstruct(imgs, mats, geom, strategy="strip2",
                                  group=16, gband=64, gwidth=64))
    np.testing.assert_allclose(out2, ref, rtol=1e-5, atol=1e-5)
