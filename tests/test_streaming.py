"""Streamed reconstruction engine: arrival-order freedom, slot reuse.

The acceptance claim: a streamed reconstruction (projections submitted
in shuffled-order chunks with explicit angle indices) matches the
one-shot ``reconstruct`` of the same filtered stack to <= 1e-5, and B
concurrent scans over fewer slots all converge to the same volume
(continuous batching).
"""

import numpy as np
import pytest

from repro.core import Geometry, filter_projections, reconstruct
from repro.core.phantom import make_dataset
from repro.streaming import ReconstructionEngine

GEOM = Geometry().scaled(16, n_proj=6)
_DS = make_dataset(GEOM)


def _oracle():
    projs, mats, _ = _DS
    filt = np.asarray(filter_projections(projs, GEOM))
    return np.asarray(reconstruct(filt, mats, GEOM))


REF = _oracle()


def test_streamed_shuffled_chunks_match_one_shot():
    projs, mats, _ = _DS
    eng = ReconstructionEngine(GEOM, n_slots=2, pbatch=4)
    sid = eng.begin_scan(n_proj=GEOM.n_proj)
    order = np.random.default_rng(7).permutation(GEOM.n_proj)
    # Ragged shuffled chunks, including a single-projection submit with
    # a scalar angle index.
    for chunk in (order[:3], order[3:5]):
        eng.submit(sid, projs[chunk], mats[chunk], chunk)
    last = int(order[5])
    eng.submit(sid, projs[last], mats[last], last)
    eng.drain()
    out = np.asarray(eng.result(sid))
    assert np.abs(out).max() > 0
    np.testing.assert_allclose(out, REF, atol=1e-5, rtol=1e-5)


def test_streamed_remainder_not_divisible_by_pbatch():
    """n_proj % pbatch != 0: the remainder folds zero-padded to the same
    compiled step, contributing exactly its own projections."""
    projs, mats, _ = _DS
    eng = ReconstructionEngine(GEOM, n_slots=1, pbatch=4)
    sid = eng.begin_scan(n_proj=GEOM.n_proj)       # 6 = 4 + 2 remainder
    idx = np.arange(GEOM.n_proj)
    eng.submit(sid, projs, mats, idx)
    eng.drain()
    np.testing.assert_allclose(np.asarray(eng.result(sid)), REF,
                               atol=1e-5, rtol=1e-5)
    assert eng.stats["folds"] == GEOM.n_proj


def test_multi_volume_continuous_batching_reuses_slots():
    """3 scans over 2 slots: the third admits only after a retirement,
    every result matches the oracle, and a freed slot is reused."""
    projs, mats, _ = _DS
    eng = ReconstructionEngine(GEOM, n_slots=2, pbatch=4)
    sids = [eng.begin_scan(n_proj=GEOM.n_proj) for _ in range(3)]
    assert eng.active == 3
    assert [s for s, _ in eng.slot_history] == [0, 1]  # third queued
    for i in range(GEOM.n_proj):                  # interleaved arrival
        for sid in sids:
            eng.submit(sid, projs[i], mats[i], i)
    eng.drain()
    assert eng.stats["retired"] == 3 and eng.active == 0
    for sid in sids:
        np.testing.assert_allclose(np.asarray(eng.result(sid)), REF,
                                   atol=1e-5, rtol=1e-5)
    slots = [s for s, _ in eng.slot_history]
    assert len(slots) == 3 and len(set(slots)) < len(slots)  # reuse
    # Retired slots were zeroed: a fresh 4th scan reconstructs cleanly.
    sid = eng.begin_scan(n_proj=GEOM.n_proj)
    eng.submit(sid, projs, mats, np.arange(GEOM.n_proj))
    eng.drain()
    np.testing.assert_allclose(np.asarray(eng.result(sid)), REF,
                               atol=1e-5, rtol=1e-5)


def test_engine_rejects_bad_submissions():
    projs, mats, _ = _DS
    eng = ReconstructionEngine(GEOM, n_slots=1, pbatch=4)
    sid = eng.begin_scan(n_proj=2)
    with pytest.raises(ValueError, match="angle ind"):
        eng.submit(sid, projs[0], mats[0], GEOM.n_proj)   # out of range
    with pytest.raises(ValueError, match="matrices"):
        eng.submit(sid, projs[:2], mats[:1], np.arange(2))
    with pytest.raises(ValueError, match="not finished"):
        eng.result(sid)
    with pytest.raises(ValueError, match="declared"):
        eng.submit(sid, projs[:3], mats[:3], np.arange(3))  # 3 > 2
    eng.submit(sid, projs[:2], mats[:2], np.arange(2))
    eng.drain()
    assert eng.scans[sid].done
    with pytest.raises(ValueError, match="finished"):
        eng.submit(sid, projs[2], mats[2], 2)           # post-retirement


def test_begin_scan_zero_n_proj_is_loud_not_full():
    """Regression: ``begin_scan(n_proj=0)`` used to fall through a
    truthiness check (``n_proj or geom.n_proj``) and silently register a
    *full* scan — a caller bug that would then block retirement forever
    waiting for projections nobody declared.  Zero and negative counts
    raise; only ``None`` means "full scan"."""
    eng = ReconstructionEngine(GEOM, n_slots=1, pbatch=4)
    with pytest.raises(ValueError, match="n_proj"):
        eng.begin_scan(n_proj=0)
    with pytest.raises(ValueError, match="n_proj"):
        eng.begin_scan(n_proj=-3)
    sid = eng.begin_scan(n_proj=None)
    assert eng.scans[sid].n_proj == GEOM.n_proj
    sid2 = eng.begin_scan(n_proj=2)
    assert eng.scans[sid2].n_proj == 2


def test_result_pop_releases_scan_state():
    """A long-running server must be able to drop retired volumes:
    result(pop=True) / release() evict the ScanState."""
    projs, mats, _ = _DS
    eng = ReconstructionEngine(GEOM, n_slots=1, pbatch=4)
    sid = eng.begin_scan(n_proj=2)
    with pytest.raises(ValueError, match="still active"):
        eng.release(sid)
    eng.submit(sid, projs[:2], mats[:2], np.arange(2))
    eng.drain()
    vol = eng.result(sid, pop=True)
    assert vol.shape == (GEOM.L,) * 3
    assert sid not in eng.scans
    eng.release(sid)                  # idempotent after eviction


def test_streamed_auto_strategy_resolves(tmp_path, monkeypatch):
    """strategy='auto' goes through the tuner cache like reconstruct
    (untuned fallback: strip2 — same result as the default engine)."""
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    from repro.tune import clear_memory_cache

    clear_memory_cache()
    projs, mats, _ = _DS
    eng = ReconstructionEngine(GEOM, n_slots=1, strategy="auto")
    assert eng.strategy == "strip2"
    sid = eng.begin_scan(n_proj=GEOM.n_proj)
    eng.submit(sid, projs, mats, np.arange(GEOM.n_proj))
    eng.drain()
    np.testing.assert_allclose(np.asarray(eng.result(sid)), REF,
                               atol=1e-5, rtol=1e-5)
