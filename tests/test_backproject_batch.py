"""Projection-batched (volume-resident) back projection vs the
sequential scalar oracle.

The loop-nest inversion (DESIGN.md §7) must not change semantics: for
every strategy, every ``pbatch`` — including ``pbatch ∤ n_proj``
remainders and border-ray geometries — the batched reconstruction
matches the sequential scalar-oracle reconstruction to fp32 rounding
(≤1e-5).  Accumulation order *within* a batch differs by construction
(contributions sum before the plane update), which is exactly what the
tolerance is for.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Geometry, filter_projections, reconstruct
from repro.core.backproject import (DEFAULT_PBATCH, STRATEGIES, GeomStatic,
                                    backproject_batch, backproject_one)
from repro.core.geometry import projection_matrix, projection_matrices
from repro.core.phantom import make_dataset
from repro.kernels.backproject_ops import pallas_backproject_batch
from repro.kernels.backproject_ref import backproject_volume_ref

GEOM = Geometry().scaled(16, n_proj=5)           # 5: prime vs pbatch 2, 3
GS = GeomStatic.of(GEOM)


@pytest.fixture(scope="module")
def ct_case():
    projs, mats, _ = make_dataset(GEOM)
    filt = np.asarray(filter_projections(projs, GEOM))
    return filt, np.asarray(mats, np.float32)


@pytest.fixture(scope="module")
def scalar_sequential(ct_case):
    filt, mats = ct_case
    return np.asarray(reconstruct(filt, mats, GEOM, strategy="scalar",
                                  pbatch=1))


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("pbatch", [2, 3])       # both 5 % pbatch != 0
def test_batched_matches_sequential_oracle(ct_case, scalar_sequential,
                                           strategy, pbatch):
    filt, mats = ct_case
    out = np.asarray(reconstruct(filt, mats, GEOM, strategy=strategy,
                                 pbatch=pbatch))
    np.testing.assert_allclose(out, scalar_sequential, rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("pbatch", [1, 4, 5, 7])
def test_batch_depth_sweep_strip2(ct_case, scalar_sequential, pbatch):
    """Depth sweep for the default strategy: exact divisor (5), clamp
    past n_proj (7), divisor-with-remainder (4), sequential (1)."""
    filt, mats = ct_case
    out = np.asarray(reconstruct(filt, mats, GEOM, strategy="strip2",
                                 pbatch=pbatch))
    np.testing.assert_allclose(out, scalar_sequential, rtol=1e-5,
                               atol=1e-5)


def test_batched_border_rays():
    """Geometry whose rays straddle the detector edge: the batched path
    must blend edge taps with implicit zeros exactly like the
    sequential scalar oracle (n_proj=5, pbatch=2 remainder)."""
    geom = Geometry().scaled(16, n_proj=5, n_u=24, n_v=18)
    rng = np.random.default_rng(3)
    imgs = rng.standard_normal(
        (geom.n_proj, geom.n_v, geom.n_u)).astype(np.float32)
    mats = np.asarray(projection_matrices(geom), np.float32)
    ref = np.asarray(reconstruct(imgs, mats, geom, strategy="scalar",
                                 pbatch=1))
    assert (ref == 0.0).any() and (ref != 0.0).any()
    for strategy in ("scalar", "gather", "strip2"):
        out = np.asarray(reconstruct(imgs, mats, geom, strategy=strategy,
                                     pbatch=2))
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_backproject_batch_accumulates_onto_volume(ct_case):
    """backproject_batch adds onto a non-zero volume like repeated
    backproject_one calls."""
    filt, mats = ct_case
    rng = np.random.default_rng(11)
    vol0 = jnp.asarray(rng.standard_normal((16, 16, 16)), jnp.float32)
    seq = vol0
    for k in range(3):
        seq = backproject_one(seq, filt[k], mats[k], GEOM,
                              strategy="gather")
    out = backproject_batch(vol0, filt[:3], mats[:3], GEOM,
                            strategy="gather", pbatch=3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(seq),
                               rtol=1e-5, atol=1e-5)


def test_sharded_batched_matches_single_device(ct_case):
    """Explicit pbatch threads through the shard_map slab path bit-for-
    bit on a 1x1 mesh (same batched helper, same depth)."""
    from repro.core.pipeline import sharded_reconstruct
    from repro.launch.mesh import make_local_mesh

    filt, mats = ct_case
    mesh = make_local_mesh(data=1, model=1)
    out = np.asarray(sharded_reconstruct(filt, mats, GEOM, mesh,
                                         strategy="gather", pbatch=3))
    single = np.asarray(reconstruct(filt, mats, GEOM, strategy="gather",
                                    pbatch=3))
    np.testing.assert_array_equal(out, single)


def test_tuned_pbatch_resolves_through_auto(ct_case, tmp_path, monkeypatch):
    """A tuned decision carrying ``pbatch`` redirects auto bitwise."""
    from repro.tune import (TunedConfig, clear_memory_cache,
                            device_identity, store_tuned)

    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    filt, mats = ct_case
    backend, device_kind = device_identity()
    cfg = TunedConfig(strategy="gather", opts={"pbatch": 3},
                      backend=backend, device_kind=device_kind,
                      us_per_call=1.0)
    store_tuned(GS, cfg)
    assert cfg.pbatch == 3
    a = np.asarray(reconstruct(filt, mats, GEOM, strategy="auto"))
    b = np.asarray(reconstruct(filt, mats, GEOM, strategy="gather",
                               pbatch=3))
    clear_memory_cache()
    np.testing.assert_array_equal(a, b)


# ----------------------------------------------------------------------
# Pallas batch kernel (interpret mode on CPU)
# ----------------------------------------------------------------------

def _pallas_ref(filt, mats, n):
    vol = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    for k in range(n):
        vol = backproject_volume_ref(vol, filt[k], mats[k], GS)
    return np.asarray(vol)


@pytest.mark.parametrize("pbatch", [1, 2, 3, 5])
def test_pallas_batch_matches_ref(ct_case, pbatch):
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    out = pallas_backproject_batch(vol0, filt, mats, GEOM, ty=4, chunk=16,
                                   band=16, width=128, pbatch=pbatch)
    np.testing.assert_allclose(np.asarray(out), _pallas_ref(filt, mats, 5),
                               rtol=1e-5, atol=1e-5)


def test_pallas_batch_border_rays():
    """Kernel-path zero-outside semantics across an in-kernel projection
    loop with a pbatch remainder."""
    geom = Geometry().scaled(16, n_proj=8, n_u=24, n_v=18)
    rng = np.random.default_rng(3)
    imgs = rng.standard_normal((3, geom.n_v, geom.n_u)).astype(np.float32)
    mats = np.stack([projection_matrix(geom, th)
                     for th in (0.7, 1.1, 2.9)]).astype(np.float32)
    vol0 = jnp.zeros((geom.L,) * 3, jnp.float32)
    ref = vol0
    for k in range(3):
        ref = backproject_one(ref, imgs[k], mats[k], geom,
                              strategy="scalar")
    out = pallas_backproject_batch(vol0, imgs, mats, geom, ty=8, chunk=16,
                                   band=16, width=128, pbatch=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(ref) == 0.0).any() and (np.asarray(ref) != 0.0).any()


@pytest.mark.parametrize("variant", [
    dict(double_buffer=True, db_depth=2),
    dict(double_buffer=True, db_depth=3),
    dict(micro=True),
], ids=["db2", "db3", "micro"])
@pytest.mark.parametrize("pbatch", [2, 5])   # 5 % 2 != 0: remainder batch
def test_pallas_batch_variants_match_ref(ct_case, variant, pbatch):
    """Interpret-mode parity of the db (depth 2 and deeper) and micro
    batch variants against the per-projection oracle, full-divisor and
    remainder depths."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    out = pallas_backproject_batch(vol0, filt, mats, GEOM, ty=4, chunk=16,
                                   band=16, width=128, pbatch=pbatch,
                                   **variant)
    np.testing.assert_allclose(np.asarray(out), _pallas_ref(filt, mats, 5),
                               rtol=1e-5, atol=1e-5)


def test_pallas_batch_db_bitwise_vs_plain(ct_case):
    """The DMA pipeline moves *when* strips are fetched, never what is
    computed: every depth's result is bit-for-bit the plain batch
    kernel's (same contributions, same accumulation order)."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    plain = np.asarray(pallas_backproject_batch(
        vol0, filt, mats, GEOM, ty=4, chunk=16, band=16, width=128,
        pbatch=2))
    for depth in (2, 3, 4):
        db = np.asarray(pallas_backproject_batch(
            vol0, filt, mats, GEOM, ty=4, chunk=16, band=16, width=128,
            pbatch=2, double_buffer=True, db_depth=depth))
        np.testing.assert_array_equal(db, plain)


@pytest.mark.parametrize("variant", [
    dict(double_buffer=True, db_depth=3),
    dict(micro=True),
], ids=["db3", "micro"])
def test_pallas_batch_variants_border_rays(variant):
    """Zero-outside semantics of both new variants across an in-kernel
    projection loop with a pbatch remainder on edge-straddling rays."""
    geom = Geometry().scaled(16, n_proj=8, n_u=24, n_v=18)
    rng = np.random.default_rng(3)
    imgs = rng.standard_normal((3, geom.n_v, geom.n_u)).astype(np.float32)
    mats = np.stack([projection_matrix(geom, th)
                     for th in (0.7, 1.1, 2.9)]).astype(np.float32)
    vol0 = jnp.zeros((geom.L,) * 3, jnp.float32)
    ref = vol0
    for k in range(3):
        ref = backproject_one(ref, imgs[k], mats[k], geom,
                              strategy="scalar")
    out = pallas_backproject_batch(vol0, imgs, mats, geom, ty=8, chunk=16,
                                   band=16, width=128, pbatch=2, **variant)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(ref) == 0.0).any() and (np.asarray(ref) != 0.0).any()


def test_pallas_batch_variant_flags_are_loud(ct_case):
    """Impossible variant combinations raise instead of silently
    preferring one: both variants at once, and a sub-2 pipeline depth."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    with pytest.raises(ValueError, match="exclusive"):
        pallas_backproject_batch(vol0, filt, mats, GEOM, ty=4, chunk=16,
                                 band=16, width=128, pbatch=2, micro=True,
                                 double_buffer=True)
    with pytest.raises(ValueError, match="db_depth"):
        pallas_backproject_batch(vol0, filt, mats, GEOM, ty=4, chunk=16,
                                 band=16, width=128, pbatch=2,
                                 double_buffer=True, db_depth=1)


def test_pallas_batch_micro_window_is_loud_or_correct():
    """The batch micro path runs the same planner-backed window check as
    the single-projection kernel: an undersized ``(micro_band,
    micro_width)`` raises before any device work (L=48 is where a 4-row
    window loses taps, tests/test_kernel_backproject.py)."""
    geom = Geometry().scaled(48, n_proj=2)
    rng = np.random.default_rng(7)
    imgs = rng.standard_normal(
        (geom.n_proj, geom.n_v, geom.n_u)).astype(np.float32)
    mats = np.asarray(projection_matrices(geom), np.float32)
    vol0 = jnp.zeros((48,) * 3, jnp.float32)
    with pytest.raises(ValueError, match="micro window"):
        pallas_backproject_batch(vol0, imgs, mats, geom, ty=8, chunk=48,
                                 band=32, width=256, pbatch=2, micro=True,
                                 micro_band=4)


def test_pallas_batch_validates_stack(ct_case):
    """Undersized strips are rejected for *every* projection of the
    stack before any device work."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    with pytest.raises(ValueError, match="does not cover"):
        pallas_backproject_batch(vol0, filt, mats, GEOM, ty=16, chunk=16,
                                 band=8, width=128, pbatch=2)


def test_pallas_batch_auto_uses_tuned_pbatch(ct_case, tmp_path,
                                             monkeypatch):
    from repro.tune import (TunedConfig, clear_memory_cache,
                            device_identity, store_tuned)

    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    backend, device_kind = device_identity()
    cfg = TunedConfig(strategy="strip2", opts={}, backend=backend,
                      device_kind=device_kind, us_per_call=1.0,
                      pallas={"ty": 4, "chunk": 16, "band": 16,
                              "width": 128, "pbatch": 2})
    store_tuned(GS, cfg)
    out_auto = pallas_backproject_batch(vol0, filt, mats, GEOM,
                                        strategy="auto")
    out_fix = pallas_backproject_batch(vol0, filt, mats, GEOM, ty=4,
                                       chunk=16, band=16, width=128,
                                       pbatch=2)
    clear_memory_cache()
    np.testing.assert_array_equal(np.asarray(out_auto),
                                  np.asarray(out_fix))


def _write_cache_file(tmp_path, pallas, version):
    """A raw on-disk tune-cache JSON (the path a fresh process resolves
    through), bypassing store_tuned so the version field is exactly what
    the test says it is."""
    import json
    import os
    from pathlib import Path

    from repro.tune import cache_key, device_identity

    backend, device_kind = device_identity()
    d = Path(os.environ["REPRO_TUNE_DIR"])
    d.mkdir(parents=True, exist_ok=True)
    doc = {"strategy": "strip2", "opts": {}, "backend": backend,
           "device_kind": device_kind, "us_per_call": 1.0,
           "pallas": pallas, "pallas_us": 1.0, "timings": [],
           "version": version}
    (d / f"{cache_key(GS, backend, device_kind)}.json").write_text(
        json.dumps(doc))


@pytest.mark.parametrize("variant", [
    {"double_buffer": True, "db_depth": 3},
    {"micro": True, "micro_group": 8, "micro_band": 8, "micro_width": 32},
], ids=["db", "micro"])
def test_tuned_batch_flags_resolve_from_v3_cache_file(ct_case, tmp_path,
                                                      monkeypatch,
                                                      variant):
    """A v3 cache file carrying ``double_buffer``/``micro`` redirects
    the batch path to the matching variant — bit-for-bit against both
    the explicit variant call and the plain batch kernel — and the old
    shed-the-flag warning never fires (warnings are errors here)."""
    import warnings

    from repro.tune import TUNE_SCHEMA_VERSION, clear_memory_cache

    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    tiles = {"ty": 4, "chunk": 16, "band": 16, "width": 128}
    _write_cache_file(tmp_path, {**tiles, "pbatch": 2, **variant},
                      TUNE_SCHEMA_VERSION)
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        out_auto = pallas_backproject_batch(vol0, filt, mats, GEOM,
                                            strategy="auto")
    out_fix = pallas_backproject_batch(vol0, filt, mats, GEOM, pbatch=2,
                                       **tiles, **variant)
    plain = pallas_backproject_batch(vol0, filt, mats, GEOM, pbatch=2,
                                     **tiles)
    clear_memory_cache()
    np.testing.assert_array_equal(np.asarray(out_auto),
                                  np.asarray(out_fix))
    # Neither variant changes the arithmetic, only its schedule — the
    # pipeline moves fetches, the micro window drops only identically-
    # zero one-hot terms.
    np.testing.assert_array_equal(np.asarray(out_auto), np.asarray(plain))


def test_v2_cache_file_is_ignored_not_misread(ct_case, tmp_path,
                                              monkeypatch):
    """A v2-era cache file (its variant flags were timed against a batch
    path that shed them) must read as *untuned* — auto falls back to the
    caller's parameters, bit-for-bit, with no warning."""
    import warnings

    from repro.tune import clear_memory_cache, load_tuned

    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    _write_cache_file(tmp_path, {"ty": 4, "chunk": 16, "band": 16,
                                 "width": 128, "pbatch": 2,
                                 "double_buffer": True}, version=2)
    assert load_tuned(GS) is None
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        out_auto = pallas_backproject_batch(vol0, filt, mats, GEOM,
                                            ty=4, chunk=16, band=16,
                                            width=128, strategy="auto")
    out_fix = pallas_backproject_batch(vol0, filt, mats, GEOM, ty=4,
                                       chunk=16, band=16, width=128)
    clear_memory_cache()
    np.testing.assert_array_equal(np.asarray(out_auto),
                                  np.asarray(out_fix))


def test_fold_projections_chunked_shuffled_and_slab(ct_case,
                                                    scalar_sequential):
    """The incremental-fold entry point: shuffled chunk folds cover the
    set once and match the one-shot reconstruction; a traced z0 folds
    into the right slab; undersized strip windows raise (same planner
    guard as reconstruct)."""
    from repro.core.backproject import fold_projections

    filt, mats = ct_case
    order = np.random.default_rng(11).permutation(GEOM.n_proj)
    vol = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    for chunk in (order[:2], order[2:5]):
        vol = fold_projections(vol, filt[chunk], mats[chunk], GEOM,
                               strategy="scalar", pbatch=2)
    np.testing.assert_allclose(np.asarray(vol), scalar_sequential,
                               atol=1e-5, rtol=1e-5)

    full = np.asarray(reconstruct(filt, mats, GEOM))
    half = GEOM.L // 2
    slab = fold_projections(jnp.zeros((half,) + (GEOM.L,) * 2,
                                      jnp.float32),
                            filt, mats, GEOM, z0=half)
    np.testing.assert_array_equal(np.asarray(slab), full[half:])

    with pytest.raises(ValueError, match="window"):
        fold_projections(vol, filt, mats, GEOM, strategy="strip2",
                         gband=2, gwidth=4)


def test_default_pbatch_is_sane():
    assert DEFAULT_PBATCH >= 1


# ----------------------------------------------------------------------
# Shared superset window (one group DMA per volume tile, DESIGN.md §10)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("pbatch", [1, 2, 3, 5])
def test_pallas_batch_shared_matches_ref(ct_case, pbatch):
    """Group-superset windows move *where* pixels are fetched from, not
    which taps contribute: parity with the per-projection oracle at a
    divisor depth, remainder depths, and the degenerate pbatch=1."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    out = pallas_backproject_batch(vol0, filt, mats, GEOM, ty=4, chunk=16,
                                   band=16, width=128, pbatch=pbatch,
                                   shared_window=True)
    np.testing.assert_allclose(np.asarray(out), _pallas_ref(filt, mats, 5),
                               rtol=1e-5, atol=1e-5)


def test_pallas_batch_shared_bitwise_vs_plain(ct_case):
    """At equal pbatch the shared kernel accumulates the same
    contributions in the same order as the plain batch kernel — the
    superset window only re-bases the in-window offsets."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    plain = np.asarray(pallas_backproject_batch(
        vol0, filt, mats, GEOM, ty=4, chunk=16, band=16, width=128,
        pbatch=2))
    shared = np.asarray(pallas_backproject_batch(
        vol0, filt, mats, GEOM, ty=4, chunk=16, band=16, width=128,
        pbatch=2, shared_window=True))
    np.testing.assert_array_equal(shared, plain)


def test_pallas_batch_shared_border_rays():
    """Zero-outside semantics through the shared slab: edge-straddling
    rays with a pbatch remainder."""
    geom = Geometry().scaled(16, n_proj=8, n_u=24, n_v=18)
    rng = np.random.default_rng(3)
    imgs = rng.standard_normal((3, geom.n_v, geom.n_u)).astype(np.float32)
    mats = np.stack([projection_matrix(geom, th)
                     for th in (0.7, 1.1, 2.9)]).astype(np.float32)
    # The host planner sizes the superset from the *submitted* matrices,
    # so hand it the same geometry object reconstruct would see.
    vol0 = jnp.zeros((geom.L,) * 3, jnp.float32)
    ref = vol0
    for k in range(3):
        ref = backproject_one(ref, imgs[k], mats[k], geom,
                              strategy="scalar")
    out = pallas_backproject_batch(vol0, imgs, mats, geom, ty=8, chunk=16,
                                   band=16, width=128, pbatch=2,
                                   shared_window=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(ref) == 0.0).any() and (np.asarray(ref) != 0.0).any()


@pytest.mark.parametrize("dtype,rel", [("bfloat16", 0.005),
                                       ("int8", 0.02)])
def test_pallas_batch_narrow_wire_differs_but_bounded(ct_case, dtype, rel):
    """Narrow wires on the batch kernel (plain and shared): observably
    different from f32 (the conversion is real) yet within a small
    fraction of the volume scale — the f32-accumulate contract,
    adversarial form.  bf16 rounds the tap values (~0.5%); int8 moves
    per-row affine codes dequantised after the gather (~2%)."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    f32 = np.asarray(pallas_backproject_batch(
        vol0, filt, mats, GEOM, ty=4, chunk=16, band=16, width=128,
        pbatch=2))
    scale = float(np.abs(f32).max())
    for flags in (dict(), dict(shared_window=True)):
        vq = np.asarray(pallas_backproject_batch(
            vol0, filt, mats, GEOM, ty=4, chunk=16, band=16, width=128,
            pbatch=2, strip_dtype=dtype, **flags))
        assert not np.array_equal(vq, f32)
        assert float(np.abs(vq - f32).max()) < rel * scale


def test_pallas_batch_int8_variants_agree_bitwise(ct_case):
    """Every batch variant (plain / shared / db / micro) dequantises the
    same codes with the same per-row scales — the DMA shape must not
    change the int8 arithmetic, so all four agree bit-for-bit."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    outs = []
    for flags in (dict(), dict(shared_window=True),
                  dict(double_buffer=True), dict(micro=True)):
        outs.append(np.asarray(pallas_backproject_batch(
            vol0, filt, mats, GEOM, ty=4, chunk=16, band=16, width=128,
            pbatch=2, strip_dtype="int8", **flags)))
    for other in outs[1:]:
        np.testing.assert_array_equal(outs[0], other)


def test_pallas_batch_shared_is_exclusive(ct_case):
    """The shared slab owns the window layout — combining it with the
    DMA pipeline or the micro window must raise, not silently pick one."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    for bad in (dict(micro=True), dict(double_buffer=True)):
        with pytest.raises(ValueError, match="exclusive"):
            pallas_backproject_batch(vol0, filt, mats, GEOM, ty=4,
                                     chunk=16, band=16, width=128,
                                     pbatch=2, shared_window=True, **bad)


def test_pallas_batch_shared_undersized_dims_raise(ct_case):
    """Explicit shared dims below the planner's group-superset
    requirement must raise before any device work — an undersized slab
    would drop taps silently."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    with pytest.raises(ValueError, match="shared window"):
        pallas_backproject_batch(vol0, filt, mats, GEOM, ty=4, chunk=16,
                                 band=16, width=128, pbatch=2,
                                 shared_window=True, shared_band=8,
                                 shared_width=128)


def test_pallas_batch_shared_needs_full_geometry(ct_case):
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    with pytest.raises(ValueError, match="Geometry"):
        pallas_backproject_batch(vol0, filt, mats, GS, ty=4, chunk=16,
                                 band=16, width=128, pbatch=2,
                                 shared_window=True)


def test_tuned_shared_window_resolves_from_cache(ct_case, tmp_path,
                                                 monkeypatch):
    """A v4 tuned decision carrying ``shared_window``/``strip_dtype``
    redirects auto to the shared bf16 kernel bit-for-bit."""
    from repro.tune import TUNE_SCHEMA_VERSION, clear_memory_cache

    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    tiles = {"ty": 4, "chunk": 16, "band": 16, "width": 128}
    _write_cache_file(tmp_path, {**tiles, "pbatch": 2,
                                 "shared_window": True,
                                 "strip_dtype": "bfloat16"},
                      TUNE_SCHEMA_VERSION)
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    out_auto = pallas_backproject_batch(vol0, filt, mats, GEOM,
                                        strategy="auto")
    out_fix = pallas_backproject_batch(vol0, filt, mats, GEOM, pbatch=2,
                                       shared_window=True,
                                       strip_dtype="bfloat16", **tiles)
    clear_memory_cache()
    np.testing.assert_array_equal(np.asarray(out_auto),
                                  np.asarray(out_fix))


def test_v3_cache_file_is_ignored_not_misread(ct_case, tmp_path,
                                              monkeypatch):
    """A v3-era decision predates the strip_dtype/shared_window axes —
    its "best" never competed against them, so it must read as untuned
    rather than freeze the old design space."""
    from repro.tune import clear_memory_cache, load_tuned

    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    _write_cache_file(tmp_path, {"ty": 4, "chunk": 16, "band": 16,
                                 "width": 128, "pbatch": 2}, version=3)
    assert load_tuned(GS) is None
    clear_memory_cache()
