"""The stable facade: ``repro.api`` and its lazy ``repro`` forwarding.

Claims under test (DESIGN.md §14):

* ``repro.__all__`` and ``repro.api.__all__`` are the same list, every
  name resolves through both paths, and both paths hand back the *same*
  object (the facade re-exports, it does not wrap).
* Option bags on the blessed entry points are keyword-only — a
  positional ``strategy`` is a ``TypeError``, not a silent misparse.
* The :class:`ProjectionChunk` submit form is the one true signature;
  the legacy positional triple still works but warns ``DeprecationWarning``
  exactly once per process.
"""

import importlib
import warnings

import numpy as np
import pytest

import repro
import repro.api as api
from repro.core import Geometry
from repro.core.phantom import make_dataset

GEOM = Geometry().scaled(16, n_proj=4)


def test_facade_all_lists_match():
    assert repro.__all__ == api.__all__


def test_every_name_resolves_identically_via_both_paths():
    for name in api.__all__:
        assert getattr(repro, name) is getattr(api, name), name


def test_lazy_forwarding_dir_and_attribute_error():
    assert set(api.__all__) <= set(dir(repro))
    with pytest.raises(AttributeError, match="no attribute"):
        repro.not_a_public_name


def test_facade_objects_are_the_defining_modules():
    from repro.core.backproject import reconstruct
    from repro.dispatch import Dispatcher
    from repro.serving.ct_frontdoor import CTFrontDoor
    from repro.streaming import ReconstructionEngine

    assert api.reconstruct is reconstruct
    assert api.Dispatcher is Dispatcher
    assert api.CTFrontDoor is CTFrontDoor
    assert api.ReconstructionEngine is ReconstructionEngine


def test_option_bags_are_keyword_only():
    projs, mats, _ = make_dataset(GEOM)
    filt = np.asarray(api.filter_projections(projs, GEOM))
    with pytest.raises(TypeError):
        api.reconstruct(filt, mats, GEOM, "strip2")   # positional strategy
    out = np.asarray(api.reconstruct(filt, mats, GEOM, strategy="strip2"))
    assert np.abs(out).max() > 0


def test_import_smoke_matches_issue_acceptance():
    mod = importlib.import_module("repro.api")
    for name in ("reconstruct", "sharded_reconstruct",
                 "reconstruct_shards", "ReconstructionEngine",
                 "Dispatcher", "ExecutionPlan", "autotune"):
        assert callable(getattr(mod, name)) or hasattr(mod, name)


# ----------------------------------------------------------------------
# ProjectionChunk and the deprecation shim
# ----------------------------------------------------------------------

def test_projection_chunk_normalises_single_projection():
    from repro.api import ProjectionChunk

    projs, mats, _ = make_dataset(GEOM)
    c = ProjectionChunk(projs[2], mats[2], 2)
    assert c.n == 1
    p, m, idx = c.arrays()
    assert p.shape == (1, GEOM.n_v, GEOM.n_u)
    assert m.shape == (1, 3, 4) and idx.tolist() == [2]
    c3 = ProjectionChunk(projs[:3], mats[:3], np.arange(3))
    assert c3.n == 3


def test_positional_submit_warns_deprecation_once():
    import repro.streaming.engine as engine_mod
    from repro.api import ProjectionChunk, ReconstructionEngine

    projs, mats, _ = make_dataset(GEOM)
    eng = ReconstructionEngine(GEOM, n_slots=1, pbatch=4)
    sid = eng.begin_scan(n_proj=GEOM.n_proj)
    engine_mod._POSITIONAL_SUBMIT_WARNED = False
    with pytest.warns(DeprecationWarning, match="ProjectionChunk"):
        eng.submit(sid, projs[:2], mats[:2], np.arange(2))
    # Once per process: the second legacy call stays quiet.
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.submit(sid, projs[2], mats[2], 2)
        # ...and the blessed form never warns.
        eng.submit(sid, ProjectionChunk(projs[3], mats[3], 3))


def test_submit_rejects_mixed_forms():
    from repro.api import ProjectionChunk, ReconstructionEngine

    projs, mats, _ = make_dataset(GEOM)
    eng = ReconstructionEngine(GEOM, n_slots=1, pbatch=4)
    sid = eng.begin_scan(n_proj=GEOM.n_proj)
    chunk = ProjectionChunk(projs[:2], mats[:2], np.arange(2))
    with pytest.raises(TypeError, match="matrix/angle_index"):
        eng.submit(sid, chunk, mats[:2], np.arange(2))
    with pytest.raises(TypeError):
        eng.submit(sid, projs[:2])          # triple with no matrices


def test_legacy_and_chunk_submissions_reconstruct_identically():
    from repro.api import (ProjectionChunk, ReconstructionEngine,
                           filter_projections, reconstruct)

    projs, mats, _ = make_dataset(GEOM)
    filt = np.asarray(filter_projections(projs, GEOM))
    ref = np.asarray(reconstruct(filt, mats, GEOM, strategy="strip2"))
    eng = ReconstructionEngine(GEOM, n_slots=1, pbatch=4)
    sid = eng.begin_scan(n_proj=GEOM.n_proj)
    idx = np.arange(GEOM.n_proj)
    eng.submit(sid, ProjectionChunk(projs, mats, idx))
    eng.drain()
    np.testing.assert_allclose(np.asarray(eng.result(sid)), ref,
                               atol=1e-5, rtol=1e-5)
