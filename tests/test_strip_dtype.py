"""The bf16-wire contract (DESIGN.md §10): ``strip_dtype`` halves the
bytes the strip strategies move without touching their tap semantics.

Three guarantees, each load-bearing:

* ``strip_dtype="float32"`` (the default) is **bitwise** the old path —
  not merely close.  The option must be free when unused.
* ``strip_dtype="bfloat16"`` casts only the *wire* (the padded detector
  image); accumulation stays f32 via an upcasting dot.  The adversarial
  bound: the bf16 volume must actually differ from the f32 one (the
  cast is real, the test cannot silently pass on a no-op) AND stay
  within a quantified quality envelope — ROI PSNR against the f32
  volume above 40 dB, phantom-PSNR degradation under 0.5 dB.  Measured
  headroom is large (ROI PSNR ≈ 73–77 dB, drop ≈ 0.0005 dB); the bound
  is where "rounding noise" ends and "wrong taps" begins.
* Unknown dtypes raise loudly — a typo must never run f32 silently.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Geometry, filter_projections
from repro.core.backproject import reconstruct, strip_wire_dtype
from repro.core.phantom import make_dataset
from repro.core.quality import psnr, roi_mask

GEOM = Geometry().scaled(16, n_proj=8)
L = GEOM.L


@pytest.fixture(scope="module")
def problem():
    projs, mats, ref = make_dataset(GEOM)
    filt = filter_projections(projs, GEOM)
    return filt, mats, ref


@pytest.mark.parametrize("strategy", ["strip", "strip2"])
def test_f32_wire_is_bitwise_unchanged(problem, strategy):
    filt, mats, _ = problem
    base = np.asarray(reconstruct(filt, mats, GEOM, strategy=strategy))
    opt = np.asarray(reconstruct(filt, mats, GEOM, strategy=strategy,
                                 strip_dtype="float32"))
    np.testing.assert_array_equal(base, opt)


@pytest.mark.parametrize("strategy", ["strip", "strip2"])
def test_bf16_wire_differs_but_bounded(problem, strategy):
    filt, mats, ref = problem
    v32 = np.asarray(reconstruct(filt, mats, GEOM, strategy=strategy))
    v16 = np.asarray(reconstruct(filt, mats, GEOM, strategy=strategy,
                                 strip_dtype="bfloat16"))
    mask = roi_mask(L)
    # Adversarial half: the cast must be observable...
    assert not np.array_equal(v16, v32), \
        "bf16 wire produced a bitwise-identical volume; the cast is dead"
    # ...and the tolerance half: observable but small, both relative to
    # the f32 volume and in end-metric (phantom PSNR) terms.
    assert float(psnr(v16, v32, mask)) > 40.0
    drop = float(psnr(v32, ref, mask)) - float(psnr(v16, ref, mask))
    assert abs(drop) < 0.5


def test_unknown_strip_dtype_raises(problem):
    filt, mats, _ = problem
    with pytest.raises(ValueError, match="strip_dtype"):
        reconstruct(filt, mats, GEOM, strategy="strip2",
                    strip_dtype="float16")
    with pytest.raises(ValueError, match="strip_dtype"):
        strip_wire_dtype("f32")


def test_wire_dtype_table():
    assert strip_wire_dtype("float32") is None
    assert strip_wire_dtype("bfloat16") is jnp.bfloat16


def test_engine_fold_accepts_bf16_wire(problem):
    """The streamed fold path threads ``strip_dtype`` end to end."""
    from repro.streaming import ReconstructionEngine

    filt, mats, _ = problem
    projs, mats_np, _ = make_dataset(GEOM)
    eng = ReconstructionEngine(GEOM, n_slots=1, pbatch=4,
                               strategy="strip2",
                               strip_dtype="bfloat16")
    sid = eng.begin_scan(n_proj=GEOM.n_proj)
    eng.submit(sid, np.asarray(projs, np.float32), mats_np,
               np.arange(GEOM.n_proj))
    eng.drain()
    v16 = np.asarray(eng.result(sid))
    v32 = np.asarray(reconstruct(filt, mats, GEOM, strategy="strip2"))
    assert float(psnr(v16, v32, roi_mask(L))) > 40.0
