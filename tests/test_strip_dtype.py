"""The strip-wire contract (DESIGN.md §10, §12): ``strip_dtype`` cuts
the bytes the strip strategies move without touching their tap
semantics.

Three guarantees, each load-bearing:

* ``strip_dtype="float32"`` (the default) is **bitwise** the old path —
  not merely close.  The option must be free when unused.
* The narrow wires touch only the *wire* (the padded detector image);
  accumulation stays f32 via an upcasting dot.  The adversarial bound:
  the narrow-wire volume must actually differ from the f32 one (the
  conversion is real, the test cannot silently pass on a no-op) AND
  stay within a quantified quality envelope.  ``"bfloat16"`` (2 B/px):
  ROI PSNR against the f32 volume above 40 dB, phantom-PSNR drop under
  0.5 dB.  ``"int8"`` (1 B/px, per-row affine codes with error-feedback
  encode, dequantised after the gather): ROI PSNR above 35 dB, drop
  under 1.0 dB.  Measured headroom is large (bf16 ROI PSNR ≈ 73–77 dB,
  int8 ≈ 57 dB); the bounds are where "rounding noise" ends and
  "wrong taps" begins.
* Unknown dtypes raise loudly at every entry layer — a typo must never
  run f32 silently — and a pre-encoded :class:`repro.quant.RowQuant`
  handed to a non-int8 sampler raises instead of being misread as
  codes.

The sharded tests re-check the same three guarantees through
``sharded_reconstruct`` on a real 2x2 device mesh (subprocess, so the
main test process keeps jax at 1 device — the test_distributed idiom).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Geometry, filter_projections
from repro.core.backproject import (GeomStatic, reconstruct, sample_strip,
                                    sample_strip2, strip_wire_dtype)
from repro.core.phantom import make_dataset
from repro.core.quality import psnr, roi_mask
from test_distributed import _run_child

GEOM = Geometry().scaled(16, n_proj=8)
L = GEOM.L

# (dtype, min ROI PSNR vs f32 volume, max phantom-PSNR drop) — the
# quality envelope each narrow wire must stay inside.
WIRES = [("bfloat16", 40.0, 0.5), ("int8", 35.0, 1.0)]


@pytest.fixture(scope="module")
def problem():
    projs, mats, ref = make_dataset(GEOM)
    filt = filter_projections(projs, GEOM)
    return filt, mats, ref


@pytest.mark.parametrize("strategy", ["strip", "strip2"])
def test_f32_wire_is_bitwise_unchanged(problem, strategy):
    filt, mats, _ = problem
    base = np.asarray(reconstruct(filt, mats, GEOM, strategy=strategy))
    opt = np.asarray(reconstruct(filt, mats, GEOM, strategy=strategy,
                                 strip_dtype="float32"))
    np.testing.assert_array_equal(base, opt)


@pytest.mark.parametrize("dtype,psnr_min,drop_max", WIRES)
@pytest.mark.parametrize("strategy", ["strip", "strip2"])
def test_narrow_wire_differs_but_bounded(problem, strategy, dtype,
                                         psnr_min, drop_max):
    filt, mats, ref = problem
    v32 = np.asarray(reconstruct(filt, mats, GEOM, strategy=strategy))
    vq = np.asarray(reconstruct(filt, mats, GEOM, strategy=strategy,
                                strip_dtype=dtype))
    mask = roi_mask(L)
    # Adversarial half: the conversion must be observable...
    assert not np.array_equal(vq, v32), \
        f"{dtype} wire produced a bitwise-identical volume; the " \
        f"conversion is dead"
    # ...and the tolerance half: observable but small, both relative to
    # the f32 volume and in end-metric (phantom PSNR) terms.
    assert float(psnr(vq, v32, mask)) > psnr_min
    drop = float(psnr(v32, ref, mask)) - float(psnr(vq, ref, mask))
    assert abs(drop) < drop_max


def test_unknown_strip_dtype_raises(problem):
    filt, mats, _ = problem
    with pytest.raises(ValueError, match="strip_dtype"):
        reconstruct(filt, mats, GEOM, strategy="strip2",
                    strip_dtype="float16")
    with pytest.raises(ValueError, match="strip_dtype"):
        strip_wire_dtype("f32")


def test_wire_dtype_table():
    assert strip_wire_dtype("float32") is None
    assert strip_wire_dtype("bfloat16") is jnp.bfloat16
    assert strip_wire_dtype("int8") is jnp.int8


@pytest.mark.parametrize("sampler", [sample_strip, sample_strip2])
def test_rowquant_image_requires_int8(sampler):
    """A pre-encoded image on a non-int8 wire must raise, not be
    silently interpreted as detector values."""
    from repro.quant import quantize_rows

    rq = quantize_rows(jnp.ones((16, 128), jnp.float32))
    gs = GeomStatic.of(GEOM)
    ixy = jnp.zeros((L, L), jnp.float32)
    for dtype in ("float32", "bfloat16"):
        with pytest.raises(TypeError, match="RowQuant"):
            sampler(rq, ixy, ixy, gs, strip_dtype=dtype)


@pytest.mark.parametrize("dtype,psnr_min,_drop", WIRES)
def test_engine_fold_accepts_narrow_wire(problem, dtype, psnr_min, _drop):
    """The streamed fold path threads ``strip_dtype`` end to end."""
    from repro.streaming import ReconstructionEngine

    filt, mats, _ = problem
    projs, mats_np, _ = make_dataset(GEOM)
    eng = ReconstructionEngine(GEOM, n_slots=1, pbatch=4,
                               strategy="strip2", strip_dtype=dtype)
    sid = eng.begin_scan(n_proj=GEOM.n_proj)
    eng.submit(sid, np.asarray(projs, np.float32), mats_np,
               np.arange(GEOM.n_proj))
    eng.drain()
    vq = np.asarray(eng.result(sid))
    v32 = np.asarray(reconstruct(filt, mats, GEOM, strategy="strip2"))
    assert float(psnr(vq, v32, roi_mask(L))) > psnr_min


def test_engine_rejects_unknown_strip_dtype():
    from repro.streaming import ReconstructionEngine

    with pytest.raises(ValueError, match="strip_dtype"):
        ReconstructionEngine(GEOM, n_slots=1, pbatch=4,
                             strategy="strip2", strip_dtype="int4")


# ----------------------------------------------------------------------
# sharded_reconstruct: the same contract on a real device mesh
# ----------------------------------------------------------------------

_SHARDED_PREFIX = """
        from repro.core import Geometry, filter_projections, reconstruct
        from repro.core.phantom import make_dataset
        from repro.core.pipeline import sharded_reconstruct
        from repro.launch.mesh import make_local_mesh
        geom = Geometry().scaled(16, n_proj=4)
        projs, mats, ref = make_dataset(geom)
        filt = np.asarray(filter_projections(projs, geom))
        mesh = make_local_mesh(data=2, model=2)
"""


def test_sharded_f32_wire_is_bitwise_unchanged():
    rec = _run_child(4, _SHARDED_PREFIX + """
        base = sharded_reconstruct(filt, mats, geom, mesh,
                                   strategy="strip2")
        opt = sharded_reconstruct(filt, mats, geom, mesh,
                                  strategy="strip2",
                                  strip_dtype="float32")
        print(json.dumps({
            "bitwise": bool(jnp.array_equal(base, opt)),
            "sum": float(jnp.sum(base))}))
    """)
    assert rec["bitwise"]
    assert rec["sum"] != 0.0


@pytest.mark.parametrize("dtype,psnr_min", [("bfloat16", 40.0),
                                            ("int8", 35.0)])
def test_sharded_narrow_wire_differs_but_bounded(dtype, psnr_min):
    rec = _run_child(4, _SHARDED_PREFIX + f"""
        from repro.core.quality import psnr, roi_mask
        v32 = sharded_reconstruct(filt, mats, geom, mesh,
                                  strategy="strip2")
        vq = sharded_reconstruct(filt, mats, geom, mesh,
                                 strategy="strip2",
                                 strip_dtype={dtype!r})
        mask = roi_mask(geom.L)
        print(json.dumps({{
            "identical": bool(jnp.array_equal(vq, v32)),
            "psnr": float(psnr(vq, v32, mask))}}))
    """)
    assert not rec["identical"], \
        f"sharded {dtype} wire was a no-op (bitwise-identical volume)"
    assert rec["psnr"] > psnr_min


def test_sharded_unknown_strip_dtype_raises():
    rec = _run_child(4, _SHARDED_PREFIX + """
        try:
            sharded_reconstruct(filt, mats, geom, mesh,
                                strategy="strip2", strip_dtype="int4")
        except ValueError as e:
            print(json.dumps({"raised": "strip_dtype" in str(e)}))
        else:
            print(json.dumps({"raised": False}))
    """)
    assert rec["raised"]
