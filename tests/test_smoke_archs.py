"""Per-arch smoke tests: reduced config, one forward/train step on CPU.

Required by the assignment: every architecture instantiates a REDUCED
config of the same family and runs one forward/train step asserting
output shapes + no NaNs.  Decode is exercised too (one token with cache),
since half the dry-run cells lower ``serve_step``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.model import (FRONTEND_DIM, decode_step, forward,
                                init_cache, init_model, loss_fn, prefill)

B, S = 2, 16


def _batch(cfg, key):
    kt, kl, kf = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab),
    }
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            kf, (B, 4, FRONTEND_DIM["vision"]), jnp.float32)
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            kf, (B, S, FRONTEND_DIM["audio"]), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_loss(arch, key):
    cfg = ARCHS[arch].reduced()
    params, specs = init_model(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = forward(params, cfg, batch, remat=False)
    seq = logits.shape[1]
    assert logits.shape[0] == B and logits.shape[2] == cfg.vocab
    assert np.all(np.isfinite(np.asarray(logits)))
    loss, metrics = loss_fn(params, cfg, batch, remat=False)
    assert np.isfinite(float(loss)) and float(loss) > 0

    # Param/spec trees are parallel.
    pl_ = jax.tree.leaves(params)
    sl = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, tuple))
    assert len(pl_) == len(sl)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_grad(arch, key):
    cfg = ARCHS[arch].reduced()
    params, _ = init_model(cfg, key)
    batch = _batch(cfg, key)

    def loss_of(p):
        return loss_fn(p, cfg, batch, remat=True)[0]

    loss, grads = jax.value_and_grad(loss_of)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch, key):
    cfg = ARCHS[arch].reduced()
    params, _ = init_model(cfg, key)
    batch = _batch(cfg, key)
    if cfg.enc_dec:
        logits, cache = prefill(params, cfg, batch, max_len=S)
        assert logits.shape == (B, 1, cfg.vocab)
        index = jnp.int32(S - 1)
    else:
        cache = init_cache(cfg, B, max_len=S)
        index = jnp.int32(0)
    logits, cache2 = decode_step(params, cfg, cache,
                                 batch["tokens"][:, :1], index)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    # Cache pytree structure is preserved by a step.
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(cache2))


@pytest.mark.parametrize("arch", ["chatglm3-6b", "xlstm-125m",
                                  "jamba-v0.1-52b"])
def test_prefill_matches_decode(arch, key):
    """Prefill-then-decode == forward on the same tokens (teacher force).

    MoE capacity dropping depends on how many tokens route together, so
    for exact equivalence the capacity factor is raised to the drop-free
    regime (capacity semantics themselves are tested in test_moe.py).
    """
    import dataclasses
    cfg = dataclasses.replace(ARCHS[arch].reduced(), capacity_factor=16.0)
    params, _ = init_model(cfg, key)
    batch = _batch(cfg, key)
    toks = batch["tokens"]
    logits_full, _ = forward(params, cfg, batch, remat=False)
    n = 6
    pre = {"tokens": toks[:, :n]}
    _, cache = prefill(params, cfg, pre, max_len=S)
    lg, _ = decode_step(params, cfg, cache, toks[:, n:n + 1],
                        jnp.int32(n))
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_full[:, n]),
                               rtol=2e-2, atol=2e-2)
