"""HLO analyzer validation: loop-weighted == unrolled, collectives, trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import parse_shape_bytes, roofline_terms
from repro.analysis.hlo_module import analyze_module


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_match_unrolled():
    x = jnp.ones((32, 64))
    ws = jnp.ones((12, 64, 64))

    def model(unroll):
        def f(x, ws):
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, ws, unroll=unroll)
            return h.sum()
        return f

    a1 = analyze_module(_hlo(model(1), x, ws))
    a12 = analyze_module(_hlo(model(12), x, ws))
    expected = 2 * 32 * 64 * 64 * 12
    assert abs(a1["flops"] - a12["flops"]) / a12["flops"] < 0.05
    assert a1["flops"] >= expected            # + elementwise tanh
    assert a1["flops"] < expected * 1.2


def test_nested_scan_multiplier():
    def f(x):
        def outer(h, _):
            def inner(g, _):
                return jnp.tanh(g @ g), None
            g, _ = jax.lax.scan(inner, h, None, length=5)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h.sum()

    a = analyze_module(_hlo(f, jnp.ones((16, 16))))
    expected = 2 * 16 * 16 * 16 * 15          # 3 * 5 nested trips
    assert a["flops"] > expected * 0.95
    assert a["flops"] < expected * 1.3


def test_census_sees_gather_in_fusion():
    table = jnp.ones((128, 8))
    ids = jnp.asarray([1, 5, 9])
    a = analyze_module(_hlo(lambda t, i: t[i], table, ids))
    assert a["census"].get("gather", 0) >= 1


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[2,3]") == 24
    assert parse_shape_bytes("(bf16[4], s8[2,2])") == 12
    assert parse_shape_bytes("pred[]") == 1


def test_roofline_dominance():
    r = roofline_terms(197e12, 819e7, 50e7)   # 1s compute, 0.01s others
    assert r["dominant"] == "compute"
    assert r["bound_s"] == pytest.approx(1.0)
    r = roofline_terms(0, 0, 50e9)
    assert r["dominant"] == "collective"
    assert r["bound_s"] == pytest.approx(1.0)
