"""Multi-device behaviour (subprocess with fake CPU devices, so the main
test process keeps jax at 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_child(ndev: int, body: str, timeout=560):
    script = textwrap.dedent(f"""
        import os, sys, json
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={ndev}")
        sys.path.insert(0, {str(os.path.join(ROOT, 'src'))!r})
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout,
                         cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_reconstruct_matches_single_device():
    rec = _run_child(4, """
        from repro.core import Geometry, filter_projections, reconstruct
        from repro.core.phantom import make_dataset
        from repro.core.pipeline import sharded_reconstruct
        from repro.launch.mesh import make_local_mesh
        geom = Geometry().scaled(16, n_proj=4)
        projs, mats, ref = make_dataset(geom)
        filt = np.asarray(filter_projections(projs, geom))
        mesh = make_local_mesh(data=2, model=2)
        out = sharded_reconstruct(filt, mats, geom, mesh,
                                  strategy="gather")
        single = reconstruct(filt, mats, geom, strategy="gather")
        print(json.dumps({
            "diff": float(jnp.max(jnp.abs(out - single))),
            "sum": float(jnp.sum(out))}))
    """)
    assert rec["diff"] < 1e-5
    assert rec["sum"] != 0.0


def test_sharded_prefiltered_false_weights_nonprefix_ranks():
    """prefiltered=False on a real 2x2 mesh: rank 1 of the proj axis
    holds a *non-prefix* angle subset, so a correct result proves the
    in-shard filter used angle-indexed Parker rows (the old prefix
    contract would have weighted ranks > 0 with rank 0's angles)."""
    rec = _run_child(4, """
        from repro.core import Geometry, filter_projections, reconstruct
        from repro.core.phantom import make_dataset
        from repro.core.pipeline import sharded_reconstruct
        from repro.launch.mesh import make_local_mesh
        geom = Geometry().scaled(16, n_proj=4)
        projs, mats, ref = make_dataset(geom)
        mesh = make_local_mesh(data=2, model=2)
        out = sharded_reconstruct(projs, mats, geom, mesh,
                                  prefiltered=False)
        filt = np.asarray(filter_projections(projs, geom))
        single = reconstruct(filt, mats, geom)
        print(json.dumps({
            "max_abs_diff": float(jnp.max(jnp.abs(out - single))),
            "nonzero": bool(jnp.any(out != 0.0)),
        }))
    """)
    assert rec["nonzero"]
    assert rec["max_abs_diff"] < 1e-5


def test_compress_psum_error_feedback():
    """int8-compressed all-reduce converges to the true mean via EF."""
    rec = _run_child(4, """
        from functools import partial
        from repro.dist.collectives import compress_psum
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        from jax.sharding import PartitionSpec as P

        @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                 out_specs=(P("data"), P("data")))
        def step(g, e):
            out, new_e = compress_psum({"g": g}, "data", {"g": e})
            return out["g"], new_e["g"]

        key = jax.random.PRNGKey(0)
        g = jax.random.normal(key, (4, 64)) * 3.0
        true_mean = jnp.mean(g, axis=0)
        e = jnp.zeros((4, 64))
        # accumulate EF over repeated reductions of the same gradient:
        # the running average of compressed means converges to the truth.
        acc = jnp.zeros((64,))
        n = 8
        for _ in range(n):
            out, e = step(g, e)
            acc = acc + out[0]
        err_one = float(jnp.max(jnp.abs(out[0] - true_mean)))
        err_avg = float(jnp.max(jnp.abs(acc / n - true_mean)))
        print(json.dumps({"err_one": err_one, "err_avg": err_avg,
                          "scale": float(jnp.max(jnp.abs(true_mean)))}))
    """)
    # single-shot int8 error bounded by quantisation step; EF average
    # must beat it by a wide margin.
    assert rec["err_one"] < 0.1 * rec["scale"] + 0.05
    assert rec["err_avg"] < rec["err_one"] / 2


def test_bucketed_psum_exact():
    rec = _run_child(2, """
        from functools import partial
        from repro.dist.collectives import bucketed_psum
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((2,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        tree = {"a": jnp.arange(8.0).reshape(2, 4),
                "b": jnp.ones((2, 3)), "c": jnp.full((2, 1), 2.0)}

        @partial(jax.shard_map, mesh=mesh,
                 in_specs=(jax.tree.map(lambda _: P("data"), tree),),
                 out_specs=jax.tree.map(lambda _: P("data"), tree))
        def red(t):
            return bucketed_psum(t, "data", min_bucket_bytes=16)

        out = red(tree)
        ref = jax.tree.map(lambda x: jnp.broadcast_to(
            x.sum(0, keepdims=True), x.shape), tree)
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(out),
                                   jax.tree.leaves(ref)))
        print(json.dumps({"diff": diff}))
    """)
    assert rec["diff"] == 0.0


def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Save on a 4-device mesh, restore onto 2 devices (elastic)."""
    d = str(tmp_path / "ck")
    _run_child(4, f"""
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.ckpt import save_checkpoint
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                           NamedSharding(mesh, P("data")))
        save_checkpoint({d!r}, 1, {{"x": x}})
        print(json.dumps({{"ok": 1}}))
    """)
    rec = _run_child(2, f"""
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.ckpt import load_checkpoint
        mesh = jax.make_mesh((2,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        sh = {{"x": NamedSharding(mesh, P("data"))}}
        out, step = load_checkpoint({d!r}, {{"x": jnp.zeros((8, 4))}},
                                    shardings=sh)
        ok = bool(jnp.all(out["x"] == jnp.arange(32.0).reshape(8, 4)))
        n_shards = len(out["x"].sharding.device_set)
        print(json.dumps({{"ok": ok, "n_shards": n_shards,
                           "step": step}}))
    """)
    assert rec["ok"] and rec["n_shards"] == 2 and rec["step"] == 1
