"""Serving-engine regressions: grouped-decode cache masking + admit path.

Two silent-wrong-result fixes pinned here:

* ``step`` advances slots in groups of equal position index, but each
  group call runs the *full* batch — before the fix, every call rewrote
  the cache rows of out-of-group slots at that group's (wrong) index, so
  any mix of prompt lengths produced corrupted continuations.
* ``_admit`` appended an unconditional argmax token after prefill,
  ignoring ``temperature`` and overshooting ``max_tokens=1``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.model import init_model
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = ARCHS["chatglm3-6b"].reduced()
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg):
    rng = np.random.default_rng(0)
    # Different lengths on purpose: equal lengths put every slot in one
    # index group and never exercise the masked merge.
    return [rng.integers(0, cfg.vocab, size=4),
            rng.integers(0, cfg.vocab, size=7)]


def test_grouped_decode_matches_single_slot_runs(tiny_lm):
    """Two slots at different positions decode exactly like solo runs."""
    cfg, params = tiny_lm
    prompts = _prompts(cfg)

    eng = ServingEngine(cfg, params, n_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_ticks=100)

    for i, p in enumerate(prompts):
        solo = ServingEngine(cfg, params, n_slots=1, max_len=64)
        ref = Request(rid=i, prompt=p, max_tokens=5)
        solo.submit(ref)
        solo.run_until_done(max_ticks=100)
        assert reqs[i].out_tokens == ref.out_tokens, \
            (i, reqs[i].out_tokens, ref.out_tokens)


def test_admit_honors_max_tokens_one(tiny_lm):
    """A max_tokens=1 request retires at admit with exactly one token."""
    cfg, params = tiny_lm
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64)
    req = Request(rid=0, prompt=_prompts(cfg)[0], max_tokens=1)
    eng.submit(req)
    eng.run_until_done(max_ticks=50)
    assert req.done
    assert len(req.out_tokens) == 1
    # ...and it never occupied a slot past admit.
    assert eng.slot_req == [None, None]


def test_admit_first_token_routed_through_sample(tiny_lm):
    """The post-prefill token respects temperature (goes via _sample)."""
    cfg, params = tiny_lm
    eng = ServingEngine(cfg, params, n_slots=1, max_len=64)
    calls = []
    orig = eng._sample

    def spy(logits, temps):
        calls.append(np.asarray(temps).copy())
        return orig(logits, temps)

    eng._sample = spy
    req = Request(rid=0, prompt=_prompts(cfg)[0], max_tokens=1,
                  temperature=0.7)
    eng.submit(req)
    eng.run_until_done(max_ticks=50)
    assert len(calls) == 1 and float(calls[0][0]) == pytest.approx(0.7)
    assert len(req.out_tokens) == 1


def test_greedy_first_token_is_argmax(tiny_lm):
    """temperature=0 keeps the pre-fix greedy behaviour bit-for-bit."""
    cfg, params = tiny_lm
    from repro.models.model import prefill

    prompt = _prompts(cfg)[0]
    logits, _ = prefill(params, cfg,
                        {"tokens": jnp.asarray(prompt, jnp.int32)[None, :]},
                        max_len=64)
    expect = int(jnp.argmax(logits[0, -1]))

    eng = ServingEngine(cfg, params, n_slots=1, max_len=64)
    req = Request(rid=0, prompt=prompt, max_tokens=1)
    eng.submit(req)
    eng.run_until_done(max_ticks=50)
    assert req.out_tokens == [expect]
