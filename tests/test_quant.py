"""repro.quant: the shared int8 error-feedback quantisation primitive.

Covers the contract both consumers rely on (DESIGN.md §12): the
per-step EF invariant, the bounded row-prefix error the sigma-delta
carry buys, exact-zero decode for all-zero rows (the padded border),
grid monotonicity/containment of 0, and the symmetric mode being the
``compress_psum`` arithmetic.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import (RowQuant, dequantize_rows, quantize_ef,
                         quantize_rows)


def _rng(seed=0):
    return np.random.default_rng(seed)


# ----------------------------------------------------------------------
# quantize_ef: the one-step primitive
# ----------------------------------------------------------------------

def test_quantize_ef_residual_identity():
    """new_error == (x + error) - dequant(q), exactly, both grids."""
    x = jnp.asarray(_rng(1).normal(size=64).astype(np.float32))
    e = jnp.asarray(_rng(2).normal(size=64).astype(np.float32) * 0.01)
    scale = jnp.float32(0.05)
    q, new_e = quantize_ef(x, scale, error=e)
    np.testing.assert_array_equal(np.asarray(new_e),
                                  np.asarray((x + e) - q * scale))
    off = jnp.float32(0.3)
    q, new_e = quantize_ef(x, scale, off, error=e)
    np.testing.assert_array_equal(
        np.asarray(new_e), np.asarray((x + e) - (q * scale + off)))


def test_quantize_ef_codes_clipped_and_integral():
    x = jnp.asarray(np.linspace(-10, 10, 101, dtype=np.float32))
    q, _ = quantize_ef(x, jnp.float32(0.01))
    qn = np.asarray(q)
    assert qn.min() == -127.0 and qn.max() == 127.0
    np.testing.assert_array_equal(qn, np.round(qn))


def test_quantize_ef_symmetric_is_exact_compress_psum_arithmetic():
    """offset=None inserts no adds on either side — the residual is
    bit-for-bit ``(x + e) - round(clip)·scale`` with no ``- 0.0`` /
    ``+ 0.0`` terms in the graph (the compress_psum arithmetic)."""
    x = jnp.asarray(_rng(8).normal(size=256).astype(np.float32))
    e = jnp.asarray(_rng(9).normal(size=256).astype(np.float32) * 1e-3)
    scale = jnp.float32(0.02)
    q, new_e = quantize_ef(x, scale, error=e)
    xp = x + e
    q_ref = jnp.clip(jnp.round(xp / scale), -127.0, 127.0)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_array_equal(np.asarray(new_e),
                                  np.asarray(xp - q_ref * scale))


# ----------------------------------------------------------------------
# quantize_rows / dequantize_rows: the row wire
# ----------------------------------------------------------------------

def test_row_roundtrip_error_bounded_by_grid_step():
    img = _rng(3).normal(size=(24, 96)).astype(np.float32)
    rq = quantize_rows(img)
    assert rq.codes.dtype == jnp.int8
    dec = np.asarray(dequantize_rows(rq))
    step = np.asarray(rq.scale)[:, None]
    # EF redistributes error; each pixel still lands within ~1.5 steps
    # (round-to-nearest half step + the carried residual's half step,
    # plus clipping slack at the range ends).
    assert np.all(np.abs(dec - img) <= 1.5 * step + 1e-7)


def test_row_prefix_sums_stay_bounded():
    """The sigma-delta property: the running sum of per-pixel errors
    along any row prefix is bounded by ~one grid step, instead of
    growing with the row length — that is what the encode-side carry
    buys over independent rounding."""
    img = _rng(4).uniform(0.49, 0.51, size=(8, 4096)).astype(np.float32)
    rq = quantize_rows(img)
    dec = np.asarray(dequantize_rows(rq))
    prefix = np.cumsum(dec - img, axis=1, dtype=np.float64)
    step = np.asarray(rq.scale)[:, None]
    assert np.all(np.abs(prefix) <= 1.01 * step + 1e-6)


def test_all_zero_rows_decode_exactly_zero():
    img = np.zeros((16, 256), np.float32)
    img[3] = _rng(5).normal(size=256).astype(np.float32)
    dec = np.asarray(dequantize_rows(quantize_rows(img)))
    zero_rows = [r for r in range(16) if r != 3]
    assert np.all(dec[zero_rows] == 0.0)


def test_zero_always_on_grid_within_half_step():
    """Rows with strictly positive values still decode ~0 for a 0 input
    — the grid is widened to contain 0 (out-of-detector taps must not
    decode to the row minimum)."""
    img = _rng(6).uniform(5.0, 9.0, size=(4, 128)).astype(np.float32)
    img[:, 0] = 0.0
    rq = quantize_rows(img)
    dec = np.asarray(dequantize_rows(rq))
    assert np.all(np.abs(dec[:, 0]) <= 0.5 * np.asarray(rq.scale) + 1e-7)


def test_symmetric_mode_zero_offset():
    img = _rng(7).normal(size=(8, 64)).astype(np.float32)
    rq = quantize_rows(img, symmetric=True)
    assert np.all(np.asarray(rq.offset) == 0.0)
    amax = np.abs(img).max(axis=1)
    np.testing.assert_allclose(np.asarray(rq.scale), amax / 127.0,
                               rtol=1e-6)


def test_quantize_rows_rejects_non_2d():
    with pytest.raises(ValueError, match="2-D"):
        quantize_rows(jnp.zeros((2, 3, 4), jnp.float32))


def test_rowquant_is_a_pytree():
    import jax

    rq = quantize_rows(jnp.ones((8, 128), jnp.float32))
    leaves = jax.tree.leaves(rq)
    assert len(leaves) == 3
    sliced = jax.tree.map(lambda a: a[:4], RowQuant(rq.codes[:, :64],
                                                    rq.scale, rq.offset))
    assert sliced.codes.shape == (4, 64)
