"""Clipping mask + strip plan vs brute force (property sweeps)."""

import numpy as np
from _prop import given, settings, st

from repro.core.clipping import (line_clip_conservative, line_clip_exact,
                                 plan_strips)
from repro.core.geometry import (Geometry, project_voxels,
                                 projection_matrix, voxel_world_coords)

GEOM = Geometry().scaled(16)


def _brute_mask(geom, A):
    """Per-voxel contribution mask straight from the definition."""
    L = geom.L
    idx = np.arange(L, dtype=np.float64)
    w = voxel_world_coords(geom, idx)
    wz, wy, wx = np.meshgrid(w, w, w, indexing="ij")
    ix, iy, ww = project_voxels(A, wx, wy, wz)
    return ((ix > -1) & (ix < geom.n_u) & (iy > -1) & (iy < geom.n_v)
            & (ww > 0))


@given(theta=st.floats(0.0, 6.28))
@settings(max_examples=25, deadline=None)
def test_exact_clip_equals_brute_force(theta):
    A = projection_matrix(GEOM, theta)
    plan = line_clip_exact(GEOM, A)
    brute = _brute_mask(GEOM, A)
    L = GEOM.L
    xs = np.arange(L)
    mask_plan = (xs[None, None, :] >= plan.x0[..., None]) \
        & (xs[None, None, :] < plan.x1[..., None])
    np.testing.assert_array_equal(mask_plan, brute)


@given(theta=st.floats(0.0, 6.28))
@settings(max_examples=25, deadline=None)
def test_conservative_contains_exact(theta):
    A = projection_matrix(GEOM, theta)
    exact = line_clip_exact(GEOM, A)
    cons = line_clip_conservative(GEOM, A)
    # Empty exact ranges (x0 == x1) sit at arbitrary positions;
    # containment is only meaningful for lines with work.
    ne = exact.x1 > exact.x0
    assert (cons.x0 <= exact.x0)[ne].all()
    assert (cons.x1 >= exact.x1)[ne].all()
    assert cons.voxels >= exact.voxels


def test_clipping_saves_work_at_scale():
    """The paper's ~10% claim, at our test geometry."""
    geom = Geometry().scaled(32)
    total_e = total_c = 0
    for theta in np.linspace(0, geom.sweep, 8, endpoint=False):
        A = projection_matrix(geom, theta)
        total_e += line_clip_exact(geom, A).voxels
        total_c += line_clip_conservative(geom, A).voxels
    assert total_e < total_c, "exact mask must save work"


@given(theta=st.floats(0.0, 6.28), chunk=st.sampled_from([4, 8, 16]))
@settings(max_examples=25, deadline=None)
def test_strip_plan_covers_all_taps(theta, chunk):
    """Every contributing bilinear tap lies inside its planned strip."""
    A = projection_matrix(GEOM, theta)
    plan = plan_strips(GEOM, A, chunk=chunk)
    brute = _brute_mask(GEOM, A)
    L = GEOM.L
    idx = np.arange(L, dtype=np.float64)
    w = voxel_world_coords(GEOM, idx)
    wz, wy, wx = np.meshgrid(w, w, w, indexing="ij")
    ix, iy, _ = project_voxels(A, wx, wy, wz)
    iix = np.floor(ix).astype(int)
    iiy = np.floor(iy).astype(int)
    for z in range(L):
        for y in range(L):
            for c in range(L // chunk):
                sl = slice(c * chunk, (c + 1) * chunk)
                contrib = brute[z, y, sl]
                if not contrib.any():
                    continue
                r0 = plan.r0[z, y, c]
                c0 = plan.c0[z, y, c]
                # padded coords of both taps of contributing voxels
                rows = iiy[z, y, sl][contrib] + 1
                cols = iix[z, y, sl][contrib] + 1
                assert (rows >= r0).all() and \
                    (rows + 1 <= r0 + plan.band - 1).all()
                assert (cols >= c0).all() and \
                    (cols + 1 <= c0 + plan.width - 1).all()
