"""Shared test fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device
(the 512-placeholder-device flag belongs to dryrun.py alone)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture
def retrace_counter():
    """Runtime half of the trace-hygiene pass (DESIGN.md §13): count
    XLA compilations of jitted entry points via their ``_cache_size()``.

    Usage::

        counter = retrace_counter(core._reconstruct_jit)
        reconstruct(...)           # first call with a new plan
        assert counter.delta() == 1
        reconstruct(...)           # same plan again
        assert counter.delta() == 1    # still: no silent retrace

    A delta above the number of distinct (shape, static-arg) plans
    means something non-hashable or freshly-constructed leaked into a
    jit boundary — the bug class the static ``jit-in-fn`` /
    ``nonhashable-static`` rules guard at source level.
    """

    class _Counter:
        def __init__(self, *fns):
            assert fns, "pass at least one jitted function"
            for f in fns:
                assert hasattr(f, "_cache_size"), (
                    f"{f} is not a jitted function with _cache_size()")
            self.fns = fns
            self.base = [f._cache_size() for f in fns]

        def delta(self) -> int:
            return sum(f._cache_size() - b
                       for f, b in zip(self.fns, self.base))

    return _Counter


@pytest.fixture(autouse=True)
def _dispatch_deterministic(monkeypatch):
    """Keep the suite deterministic: an untuned ``strategy="auto"``
    falls back to strip2 (the pre-dispatch contract) instead of timing
    candidates in situ.  Dispatch tests opt back in explicitly with
    ``Dispatcher(insitu=True)``; any test-installed process dispatcher
    is dropped afterwards so state never leaks across tests."""
    monkeypatch.setenv("REPRO_DISPATCH_INSITU", "0")
    yield
    from repro.dispatch import reset_dispatcher

    reset_dispatcher()
