"""Shared test fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device
(the 512-placeholder-device flag belongs to dryrun.py alone)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
