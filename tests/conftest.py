"""Shared test fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device
(the 512-placeholder-device flag belongs to dryrun.py alone)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _dispatch_deterministic(monkeypatch):
    """Keep the suite deterministic: an untuned ``strategy="auto"``
    falls back to strip2 (the pre-dispatch contract) instead of timing
    candidates in situ.  Dispatch tests opt back in explicitly with
    ``Dispatcher(insitu=True)``; any test-installed process dispatcher
    is dropped afterwards so state never leaks across tests."""
    monkeypatch.setenv("REPRO_DISPATCH_INSITU", "0")
    yield
    from repro.dispatch import reset_dispatcher

    reset_dispatcher()
