"""Property-test shim: hypothesis when installed, seeded numpy otherwise.

The property suites are written against the hypothesis ``@given`` /
``@settings`` / ``strategies`` API.  On a bare CPU box without hypothesis
this module provides a drop-in fallback: each strategy draws from a
seeded ``numpy.random.Generator`` (seed derived from the test name, so
runs are reproducible), the first two examples pin the domain endpoints,
and the falsifying example is printed before the original failure
propagates.  No shrinking — the fallback trades minimality for zero
dependencies.

Usage (identical under both backends)::

    from _prop import given, settings, st

    @given(theta=st.floats(0.0, 6.28), z=st.integers(0, 15))
    @settings(max_examples=20, deadline=None)
    def test_something(theta, z): ...
"""

from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    class _Floats:
        def __init__(self, lo, hi):
            self.lo, self.hi = float(lo), float(hi)

        def example(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return float(rng.uniform(self.lo, self.hi))

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def example(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom:
        def __init__(self, options):
            self.options = list(options)

        def example(self, rng, i):
            if i < len(self.options):
                return self.options[i]
            return self.options[int(rng.integers(len(self.options)))]

    class _St:
        floats = staticmethod(_Floats)
        integers = staticmethod(_Integers)
        sampled_from = staticmethod(_SampledFrom)

    st = _St()

    def settings(max_examples: int = 20, deadline=None, **_kw):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # Deliberately no functools.wraps: pytest must see the
            # wrapper's bare (no-parameter) signature, not the wrapped
            # function's drawn parameters (it would hunt for fixtures
            # named after them).
            def wrapper():
                # @settings may sit above or below @given; check the
                # wrapper first so both orders take effect.
                n = getattr(wrapper, "_prop_max_examples",
                            getattr(fn, "_prop_max_examples", 20))
                seed = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = np.random.default_rng((seed, i))
                    drawn = {name: s.example(rng, i)
                             for name, s in strategies.items()}
                    try:
                        fn(**drawn)
                    except Exception:
                        print(f"Falsifying example ({fn.__qualname__}, "
                              f"example {i}/{n}): {drawn}")
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
