"""gather_ops strategy equivalence + RoPE/attention layer properties."""

import jax
import jax.numpy as jnp
import numpy as np
from _prop import given, settings, st

from repro.core.gather_ops import gather, onehot_gather, take_gather


@given(V=st.integers(3, 300), D=st.sampled_from([4, 32]),
       N=st.integers(1, 64), seed=st.integers(0, 10),
       chunk=st.sampled_from([16, 64, 2048]))
@settings(max_examples=40, deadline=None)
def test_gather_impl_equivalence(V, D, N, seed, chunk):
    key = jax.random.PRNGKey(seed)
    table = jax.random.normal(key, (V, D), jnp.float32)
    ids = jax.random.randint(key, (N,), 0, V)
    a = take_gather(table, ids)
    b = onehot_gather(table, ids, chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_gather_auto_dispatch():
    key = jax.random.PRNGKey(0)
    small = jax.random.normal(key, (100, 8))
    big = jax.random.normal(key, (5000, 8))
    ids = jnp.asarray([0, 1, 2])
    np.testing.assert_allclose(np.asarray(gather(small, ids, "auto")),
                               np.asarray(take_gather(small, ids)),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gather(big, ids, "auto")),
                               np.asarray(take_gather(big, ids)),
                               rtol=1e-6)


def test_onehot_gather_differentiable_scatter_add():
    """d/dtable of onehot gather is the scatter-add (training-safe)."""
    table = jnp.ones((10, 4))
    ids = jnp.asarray([3, 3, 7])

    def f(t):
        return jnp.sum(onehot_gather(t, ids, chunk=4))

    g = jax.grad(f)(table)
    assert float(g[3, 0]) == 2.0 and float(g[7, 0]) == 1.0
    assert float(g[0, 0]) == 0.0


# ----------------------------------------------------------------------
# RoPE / attention properties
# ----------------------------------------------------------------------

def test_rope_preserves_norm_and_relative_phase():
    from repro.models.layers import apply_rope
    B, S, H, hd = 2, 8, 2, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    q2, k2 = apply_rope(q, q, pos, hd, 1e4, "standard")
    np.testing.assert_allclose(np.linalg.norm(np.asarray(q2), axis=-1),
                               np.linalg.norm(np.asarray(q), axis=-1),
                               rtol=1e-5)
    # Relative property: <rope(q,m), rope(k,n)> depends only on m-n.
    qs, ks = apply_rope(q, q, pos + 5, hd, 1e4, "standard")
    dot_a = np.einsum("bshd,bshd->bsh", np.asarray(q2), np.asarray(k2))
    dot_b = np.einsum("bshd,bshd->bsh", np.asarray(qs), np.asarray(ks))
    np.testing.assert_allclose(dot_a, dot_b, rtol=1e-4, atol=1e-4)


def test_mrope_equals_standard_for_text():
    """Equal (t,h,w) position components reduce M-RoPE to RoPE."""
    from repro.models.layers import apply_rope
    B, S, H, hd = 1, 6, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos3 = jnp.broadcast_to(pos, (3, B, S))
    a, _ = apply_rope(q, q, pos, hd, 1e4, "standard")
    b, _ = apply_rope(q, q, pos3, hd, 1e4, "mrope")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_equals_dense():
    from repro.models.attention import (_chunked_attention,
                                        _dense_attention, _group)
    B, S, KV, G, hd = 2, 32, 2, 2, 8
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (B, S, KV * G, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    qg = _group(q, KV)
    dense = _dense_attention(qg, k, v, causal=True)
    for chunk in (4, 8, 16):
        chunked = _chunked_attention(qg, k, v, True, chunk)
        np.testing.assert_allclose(np.asarray(chunked),
                                   np.asarray(dense),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_decode_offset():
    """Chunked attention with q_offset masks exactly like dense."""
    from repro.models.attention import (_chunked_attention,
                                        _dense_attention, _group)
    B, T, KV, G, hd = 1, 16, 1, 2, 8
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, 1, KV * G, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, KV, hd))
    qg = _group(q, KV)
    for idx in (0, 5, 15):
        dense = _dense_attention(qg, k, v, True, q_offset=idx)
        chunked = _chunked_attention(qg, k, v, True, 4, q_offset=idx)
        np.testing.assert_allclose(np.asarray(chunked),
                                   np.asarray(dense), rtol=2e-3,
                                   atol=2e-3)
