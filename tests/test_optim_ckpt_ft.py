"""Optimizer, checkpointing and fault-tolerance behaviour tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.ft.manager import (FaultTolerantLoop, Preempted,
                              PreemptionSimulator, run_with_restarts)
from repro.training.optim import (AdamWConfig, adamw_update,
                                  init_opt_state, opt_state_specs)


def _quad_problem():
    params = {"w": jnp.asarray([2.0, -3.0, 1.0]),
              "b": jnp.asarray([[0.5, -0.5], [1.0, 2.0]])}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    return params, loss


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges(state_dtype):
    params, loss = _quad_problem()
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, state_dtype=state_dtype,
                      warmup_steps=0, total_steps=10_000)
    opt = init_opt_state(params, cfg)
    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, opt, stats = adamw_update(grads, params, opt, cfg)
    l1 = float(loss(params))
    assert l1 < 0.05 * l0, f"{state_dtype}: {l0} -> {l1}"
    assert np.isfinite(float(stats["grad_norm"]))


def test_int8_state_memory_shape():
    params, _ = _quad_problem()
    cfg = AdamWConfig(state_dtype="int8")
    opt = init_opt_state(params, cfg)
    assert opt["m"]["b"]["q"].dtype == jnp.int8
    assert opt["m"]["b"]["s"].shape == (2, 1)
    specs = opt_state_specs({"w": ("tp",), "b": ("fsdp", "tp")}, "int8")
    assert specs["m"]["b"] == {"q": ("fsdp", "tp"), "s": ("fsdp", "null")}


def test_grad_clip_applied():
    params, _ = _quad_problem()
    w_before = np.asarray(params["w"]).copy()   # params are donated
    cfg = AdamWConfig(lr=1e-3, clip_norm=1e-6, weight_decay=0.0)
    opt = init_opt_state(params, cfg)
    huge = jax.tree.map(lambda p: 1e9 * jnp.ones_like(p), params)
    new_params, _, stats = adamw_update(huge, params, opt, cfg)
    delta = float(np.max(np.abs(np.asarray(new_params["w"]) - w_before)))
    assert delta < 1e-3


# ----------------------------------------------------------------------

def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "nested": {"b": jnp.ones((4,), jnp.int8)},
            "step": jnp.int32(7)}
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, tree)
    save_checkpoint(d, 7, jax.tree.map(lambda a: a * 0, tree))
    like = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
    out, step = load_checkpoint(d, like)
    assert step == 7
    assert float(jnp.sum(jnp.abs(out["a"].astype(jnp.float32)))) == 0.0
    out3, _ = load_checkpoint(d, like, step=3)
    np.testing.assert_array_equal(np.asarray(out3["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    # No .tmp dirs linger.
    assert not [n for n in os.listdir(d) if n.endswith(".tmp")]


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"x": jnp.zeros((2,))}
    for s in range(6):
        save_checkpoint(d, s, tree, keep=2)
    from repro.ckpt.checkpoint import all_steps
    assert all_steps(d) == [4, 5]


def test_async_manager(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    tree = {"x": jnp.arange(4.0)}
    mgr.save_async(1, tree)
    mgr.save_async(2, jax.tree.map(lambda a: a + 1, tree))
    mgr.wait()
    assert mgr.latest_step() == 2
    out, step = mgr.restore({"x": jnp.zeros(4)})
    np.testing.assert_allclose(np.asarray(out["x"]),
                               np.arange(4.0) + 1)


# ----------------------------------------------------------------------

def test_preemption_resume_bit_exact(tmp_path):
    """Training interrupted by preemption resumes to the same result."""
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0)

    def init_fn():
        params = {"w": jnp.asarray([2.0, -3.0, 1.0])}
        return {"params": params, "opt": init_opt_state(params, cfg)}

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    def step_fn(state, step):
        grads = jax.grad(loss)(state["params"])
        p, o, stats = adamw_update(grads, state["params"], state["opt"],
                                   cfg)
        return {"params": p, "opt": o}, stats

    n_steps = 30
    # Uninterrupted reference.
    ref = init_fn()
    for s in range(n_steps):
        ref, _ = step_fn(ref, s)

    sim = PreemptionSimulator({11, 23})
    fired = set()

    def health(step):
        if step in sim.at_steps and step not in fired:
            fired.add(step)
            return True
        return False

    def make_loop():
        return FaultTolerantLoop(str(tmp_path / "ck"), save_every=5,
                                 health=health)

    state, step, restarts = run_with_restarts(
        make_loop, init_fn, step_fn, n_steps)
    assert restarts == 2
    assert step == n_steps
    np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                               np.asarray(ref["params"]["w"]),
                               rtol=1e-6, atol=1e-7)


def test_straggler_detection():
    import time
    loop = FaultTolerantLoop("/tmp/unused_ck_dir", save_every=0)

    def step_fn(state, step):
        if step == 12:
            time.sleep(0.25)
        else:
            time.sleep(0.005)
        return state, {}

    loop.run({}, 0, 20, step_fn)
    assert 12 in loop.stragglers
