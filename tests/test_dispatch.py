"""Dispatch layer: ExecutionPlan + Dispatcher (DESIGN.md §11).

Covers the three resolution outcomes — cache hit, in-situ first-call
selection, structured fallback — plus the plan's hash-equality contract
and the streaming engine's tuned-kernel fold.  The suite-wide conftest
forces ``REPRO_DISPATCH_INSITU=0``; tests that exercise selection opt
back in with ``Dispatcher(insitu=True)``.
"""

import json
import logging
import os
from pathlib import Path

import numpy as np
import pytest

from repro.core import Geometry, filter_projections, reconstruct
from repro.core.backproject import DEFAULT_PBATCH, GeomStatic
from repro.core.phantom import make_dataset
from repro.dispatch import (Dispatcher, ExecutionPlan, get_dispatcher,
                            insitu_candidates, set_dispatcher)
from repro.tune import (TUNE_SCHEMA_VERSION, TunedConfig,
                        clear_memory_cache, device_identity, store_tuned)
from repro.tune.sweep import SweepResult, Timing

GEOM = Geometry().scaled(16, n_proj=4)
GS = GeomStatic.of(GEOM)


@pytest.fixture(autouse=True)
def _isolated_tune_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    yield tmp_path / "tune"
    clear_memory_cache()


@pytest.fixture(scope="module")
def ct_case():
    projs, mats, _ = make_dataset(GEOM)
    filt = np.asarray(filter_projections(projs, GEOM))
    return filt, mats


def _fake_sweep_result():
    gather = Timing(label="gather[pbatch=2]", strategy="gather",
                    opts=(("pbatch", 2),), us_per_call=11.0, gups=1.0)
    strip2 = Timing(label="strip2[pbatch=4]", strategy="strip2",
                    opts=(("pbatch", 4),), us_per_call=22.0, gups=1.0)
    return SweepResult(geom_key=tuple(GS), backend="cpu",
                       device_kind="cpu", timings=[gather, strip2],
                       skipped=[])


# ----------------------------------------------------------------------
# ExecutionPlan
# ----------------------------------------------------------------------

def test_plan_hash_equality_across_construction_paths():
    """Identical configurations hash equal no matter how the plan was
    built — the property that keeps one compiled executable per
    configuration."""
    backend, device_kind = device_identity()
    cfg = TunedConfig(strategy="strip2", opts={"pbatch": 2},
                      backend=backend, device_kind=device_kind,
                      us_per_call=1.0)
    a = ExecutionPlan.explicit("strip2", pbatch=2)
    b = ExecutionPlan.from_tuned(cfg)
    assert a == b and hash(a) == hash(b)
    assert {a: "compiled"}[b] == "compiled"
    assert a.label == "strip2@p2"


def test_plan_explicit_validates_strictly():
    with pytest.raises(ValueError, match="auto"):
        ExecutionPlan.explicit("fastest")
    # A known key the named strategy does not accept is a caller bug.
    with pytest.raises(ValueError, match="gband"):
        ExecutionPlan.explicit("onehot", {"gband": 8})
    # A key no strategy accepts is a typo.
    with pytest.raises(ValueError, match="unknown option"):
        ExecutionPlan.explicit("strip2", {"gbnad": 8})


def test_plan_from_tuned_merges_and_flags_kernel():
    backend, device_kind = device_identity()
    cfg = TunedConfig(strategy="strip2", opts={"group": 8, "pbatch": 2},
                      backend=backend, device_kind=device_kind,
                      us_per_call=10.0,
                      pallas={"ty": 8, "chunk": 16, "band": 16,
                              "width": 128, "pbatch": 2},
                      pallas_us=5.0)
    plan = ExecutionPlan.from_tuned(cfg, {"gband": 16})
    assert plan.strategy == "strip2" and plan.pbatch == 2
    assert plan.jnp_opts() == {"group": 8, "gband": 16}
    assert plan.use_pallas and plan.pallas_opts()["ty"] == 8
    # Kernel slower than the jnp nest -> carried but not taken.
    slower = ExecutionPlan.from_tuned(
        TunedConfig(strategy="strip2", opts={}, backend=backend,
                    device_kind=device_kind, us_per_call=10.0,
                    pallas={"ty": 8, "chunk": 16, "band": 16,
                            "width": 128}, pallas_us=50.0))
    assert slower.pallas is not None and not slower.use_pallas


# ----------------------------------------------------------------------
# Fallback (selection unavailable)
# ----------------------------------------------------------------------

def test_fallback_warns_once_with_key_and_matches_strip2(ct_case, caplog):
    """Untuned + in-situ disabled: one structured warning naming the
    cache key, then the pre-dispatch strip2 default bit-for-bit."""
    filt, mats = ct_case
    d = Dispatcher(insitu=False)
    from repro.tune import cache_key
    key = cache_key(GS, d.backend, d.device_kind)
    with caplog.at_level(logging.WARNING, logger="repro.dispatch"):
        plan = d.resolve(GEOM)
        d.resolve(GEOM)                      # warn-once per (surface, key)
    warns = [r for r in caplog.records if "falling back" in r.message]
    assert len(warns) == 1
    assert key in warns[0].message
    assert "REPRO_DISPATCH_INSITU" in warns[0].message
    assert plan == ExecutionPlan.explicit("strip2")
    set_dispatcher(d)
    a = np.asarray(reconstruct(filt, mats, GEOM, strategy="auto"))
    b = np.asarray(reconstruct(filt, mats, GEOM, strategy="strip2"))
    np.testing.assert_array_equal(a, b)


def test_resolve_kernel_fallback_and_hit(caplog):
    d = Dispatcher(insitu=False)
    with caplog.at_level(logging.WARNING, logger="repro.dispatch"):
        assert d.resolve_kernel(GEOM) is None
    assert any("falling back" in r.message for r in caplog.records)
    backend, device_kind = device_identity()
    store_tuned(GS, TunedConfig(
        strategy="strip2", opts={}, backend=backend,
        device_kind=device_kind, us_per_call=1.0,
        pallas={"ty": 8, "chunk": 16, "band": 16, "width": 128,
                "micro": True, "micro_group": 8, "micro_band": 12,
                "micro_width": 64}))
    tiles = Dispatcher(insitu=False).resolve_kernel(GEOM)
    assert tiles["micro"] and tiles["micro_band"] == 12


# ----------------------------------------------------------------------
# In-situ first-call selection
# ----------------------------------------------------------------------

def test_insitu_shortlist_is_deterministic():
    a = insitu_candidates(GS, topk=6)
    b = insitu_candidates(GS, topk=6)
    assert [c.label for c in a] == [c.label for c in b]
    strategies = [c.strategy for c in a]
    assert strategies[0] == "strip2"
    assert len(a) <= 6 and len(set(map(id, a))) == len(a)
    with_pallas = insitu_candidates(GS, topk=6, include_pallas=True)
    assert any(c.strategy == "pallas" for c in with_pallas)
    assert all(c.pbatch > 1 for c in with_pallas
               if c.strategy == "pallas")


def test_insitu_selects_persists_and_never_retimes(tmp_path, caplog):
    """Miss -> one sweep over the shortlist, winner persisted as a
    schema-current cache file; every later resolve (same or fresh
    dispatcher) is a lookup with zero timing calls."""
    calls = []

    def fake_sweep(geom, *, space, warmup, iters, min_total_s):
        calls.append((len(space), warmup, iters, min_total_s))
        return _fake_sweep_result()

    d = Dispatcher(insitu=True, sweep_fn=fake_sweep)
    with caplog.at_level(logging.INFO, logger="repro.dispatch"):
        plan = d.resolve(GEOM)
    assert len(calls) == 1
    assert calls[0][1:] == (1, 1, 0.0)       # warmup=1, iters=1, pinned
    assert plan == ExecutionPlan.explicit("gather", pbatch=2)
    sel = [r for r in caplog.records if "in-situ selection" in r.message]
    assert len(sel) == 1 and "winner=gather" in sel[0].message

    files = list(Path(os.environ["REPRO_TUNE_DIR"]).glob("*.json"))
    assert len(files) == 1
    data = json.loads(files[0].read_text())
    assert data["version"] == TUNE_SCHEMA_VERSION
    assert data["strategy"] == "gather"
    assert data["opts"]["pbatch"] == 2
    assert len(data["timings"]) == 2         # evidence rides along

    # Same dispatcher: memo hit.
    assert d.resolve(GEOM) == plan and len(calls) == 1

    # Fresh dispatcher (fresh process stand-in): disk hit, no timing.
    def boom(*a, **k):
        raise AssertionError("re-timed a cached key")

    clear_memory_cache()
    d2 = Dispatcher(insitu=True, sweep_fn=boom)
    assert d2.resolve(GEOM) == plan

    # A bare GeomStatic cannot be timed -> still served from the cache.
    assert d2.resolve(GS) == plan


def test_insitu_plan_matches_offline_tuned_path_bitwise(ct_case):
    """Acceptance: the in-situ winner reconstructs bit-for-bit with the
    explicitly-named winner (same plan object, same jit cache entry)."""
    filt, mats = ct_case
    d = Dispatcher(insitu=True,
                   sweep_fn=lambda g, **k: _fake_sweep_result())
    set_dispatcher(d)
    a = np.asarray(reconstruct(filt, mats, GEOM, strategy="auto"))
    b = np.asarray(reconstruct(filt, mats, GEOM, strategy="gather",
                               pbatch=2))
    np.testing.assert_array_equal(a, b)


def test_insitu_real_sweep_end_to_end(ct_case, caplog):
    """One real (untimed-fast) selection on this backend: times the
    shortlist, persists a loadable decision, and auto then matches the
    explicit call of whatever won."""
    filt, mats = ct_case
    d = Dispatcher(insitu=True, topk=2, include_pallas=False)
    with caplog.at_level(logging.INFO, logger="repro.dispatch"):
        plan = d.resolve(GEOM)
    assert any("in-situ selection" in r.message for r in caplog.records)
    assert plan.strategy in ("strip2", "gather")
    assert len(list(Path(os.environ["REPRO_TUNE_DIR"]).glob("*.json"))) \
        == 1
    set_dispatcher(d)
    a = np.asarray(reconstruct(filt, mats, GEOM, strategy="auto"))
    b = np.asarray(reconstruct(filt, mats, GEOM, strategy=plan.strategy,
                               pbatch=plan.pbatch, **plan.jnp_opts()))
    np.testing.assert_array_equal(a, b)


def test_env_flag_gates_insitu(monkeypatch):
    """REPRO_DISPATCH_INSITU=0 (the conftest default here) disables
    selection; flipping it on enables it without constructor args."""
    calls = []

    def fake_sweep(geom, **kw):
        calls.append(1)
        return _fake_sweep_result()

    d = Dispatcher(sweep_fn=fake_sweep)          # insitu=None -> env
    assert d.resolve(GEOM).strategy == "strip2" and not calls
    monkeypatch.setenv("REPRO_DISPATCH_INSITU", "1")
    assert Dispatcher(sweep_fn=fake_sweep).resolve(GEOM).strategy \
        == "gather"
    assert len(calls) == 1


# ----------------------------------------------------------------------
# Streaming engine: tuned kernel fold
# ----------------------------------------------------------------------

def test_engine_runs_tuned_pallas_batch_plan(ct_case):
    """A cached decision whose Pallas batch kernel beat the jnp nest
    makes the engine fold through that kernel (stats prove it), with
    streamed-vs-oneshot parity at fp32 rounding."""
    from repro.streaming.engine import ReconstructionEngine

    filt, mats = ct_case
    backend, device_kind = device_identity()
    store_tuned(GS, TunedConfig(
        strategy="strip2", opts={}, backend=backend,
        device_kind=device_kind, us_per_call=100.0,
        pallas={"ty": 8, "chunk": 16, "band": 16, "width": 128,
                "pbatch": 2},
        pallas_us=10.0))
    projs, pmats, _ = make_dataset(GEOM)
    eng = ReconstructionEngine(GEOM, n_slots=1, strategy="auto")
    assert eng.exec_plan.use_pallas and eng.pbatch == 2
    sid = eng.begin_scan()
    for i in range(GEOM.n_proj):
        eng.submit(sid, projs[i], pmats[i], i)
    eng.drain()
    out = np.asarray(eng.result(sid, pop=True))
    assert eng.stats["pallas_folds"] == GEOM.n_proj
    ref = np.asarray(reconstruct(filt, mats, GEOM))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_engine_untuned_fold_unchanged(ct_case):
    """No kernel decision -> the vmapped jnp fold, zero pallas folds."""
    from repro.streaming.engine import ReconstructionEngine

    filt, mats = ct_case
    projs, pmats, _ = make_dataset(GEOM)
    eng = ReconstructionEngine(GEOM, n_slots=1, strategy="auto")
    assert eng.exec_plan.use_pallas is False
    sid = eng.begin_scan()
    for i in range(GEOM.n_proj):
        eng.submit(sid, projs[i], pmats[i], i)
    eng.drain()
    out = np.asarray(eng.result(sid, pop=True))
    assert eng.stats["pallas_folds"] == 0
    ref = np.asarray(reconstruct(filt, mats, GEOM))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_process_dispatcher_is_singleton():
    d = get_dispatcher()
    assert get_dispatcher() is d
    other = Dispatcher(insitu=False)
    assert set_dispatcher(other) is d
    assert get_dispatcher() is other
