"""End-to-end system tests: full CT pipeline, LM training run, serving."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Geometry, filter_projections, quality_report,
                        reconstruct)
from repro.core.phantom import make_dataset


def test_ct_pipeline_end_to_end():
    """Scan -> filter -> back-project -> quality, in density units."""
    geom = dataclasses.replace(Geometry().scaled(32, n_proj=48),
                               sweep=2 * math.pi)
    projs, mats, ref = make_dataset(geom)
    filt = filter_projections(projs, geom)
    vol = reconstruct(filt, mats, geom, strategy="gather")
    q = quality_report(vol, ref)
    # Absolute levels reconstruct: interior density ~0.2-1.0 region
    assert q["psnr_roi_db"] > 14.0, q
    centre = float(vol[16, 16, 16])
    assert abs(centre - ref[16, 16, 16]) < 0.25


def test_short_scan_parker_weights_match_full_scan():
    g_short = Geometry().scaled(24, n_proj=48)            # 200 degrees
    g_full = dataclasses.replace(g_short, sweep=2 * math.pi)
    out = {}
    for name, g in (("short", g_short), ("full", g_full)):
        projs, mats, ref = make_dataset(g)
        filt = filter_projections(projs, g)
        vol = reconstruct(filt, mats, g, strategy="gather")
        out[name] = quality_report(vol, ref)["psnr_roi_db"]
    # Parker-weighted short scan within ~4 dB of the full scan.
    assert out["short"] > out["full"] - 4.0, out


def test_lm_training_loss_decreases():
    """~0.5M-param model on the synthetic Markov stream: loss must drop."""
    from repro.configs import ARCHS
    from repro.data.tokens import TokenDataset
    from repro.models.model import init_model
    from repro.training import AdamWConfig, init_opt_state, make_train_step

    cfg = dataclasses.replace(ARCHS["chatglm3-6b"].reduced(), vocab=128)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                          weight_decay=0.0)
    opt = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=False))
    ds = TokenDataset(vocab=cfg.vocab, seq_len=32, global_batch=8)
    losses = []
    for s in range(30):
        batch = ds.batch(jnp.int32(s))
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_grad_accumulation_matches_full_batch():
    from repro.configs import ARCHS
    from repro.models.model import init_model
    from repro.training import AdamWConfig, init_opt_state, make_train_step

    cfg = dataclasses.replace(ARCHS["internlm2-20b"].reduced(),
                              vocab=64, param_dtype="float32")
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0)
    key = jax.random.PRNGKey(5)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, 64),
             "labels": jax.random.randint(
                 jax.random.fold_in(key, 1), (8, 16), 0, 64)}

    outs = {}
    for accum in (1, 4):
        p = jax.tree.map(jnp.copy, params)
        o = init_opt_state(p, opt_cfg)
        step = make_train_step(cfg, opt_cfg, remat=False,
                               accum_steps=accum)
        p, o, m = step(p, o, batch)
        outs[accum] = p
    diff = max(float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(outs[1]),
                        jax.tree.leaves(outs[4])))
    assert diff < 5e-3, diff


def test_serving_engine_continuous_batching():
    from repro.configs import ARCHS
    from repro.models.model import init_model
    from repro.serving import Request, ServingEngine

    cfg = ARCHS["chatglm3-6b"].reduced()
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=5 + i),
                    max_tokens=6)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    ticks = eng.run_until_done(max_ticks=200)
    assert ticks < 200
    for r in reqs:
        assert r.done and len(r.out_tokens) >= 6
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_data_pipeline_deterministic_and_learnable():
    from repro.data.tokens import TokenDataset
    ds = TokenDataset(vocab=64, seq_len=16, global_batch=4)
    b1 = ds.batch(jnp.int32(7))
    b2 = ds.batch(jnp.int32(7))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = ds.batch(jnp.int32(8))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # Markov structure: unigram distribution must be non-uniform.
    toks = np.asarray(ds.batch(jnp.int32(0))["tokens"]).ravel()
    counts = np.bincount(toks, minlength=64)
    assert counts.max() > 3 * max(counts.mean(), 1)
