"""Kernel contract checker (DESIGN.md §13): ledger, budget, hygiene,
cache audit, Dispatcher wiring, and the CLI's exit-code contract.

The seeded known-bad fixtures under ``tests/lint_fixtures/`` are the
true-positive half of the suite; the clean-tree runs are the
false-positive gate.
"""

import json
import logging
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import (VMEM_BUDGET_BYTES, ReplayCase,
                                 audit_cache_file, audit_tuned_config,
                                 batch_vmem_estimate, check_source,
                                 replay, replay_fixture,
                                 run_cache_audit_pass, run_hygiene_pass)
from repro.analysis.lint.cache_audit import geometry_for, parse_cache_key
from repro.core import Geometry, reconstruct
from repro.core.backproject import GeomStatic
from repro.tune import TunedConfig, clear_memory_cache, store_tuned
from repro.tune.space import pallas_batch_fits_vmem

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "lint_fixtures"

GEOM = Geometry().scaled(16, n_proj=4)
GS = GeomStatic.of(GEOM)


@pytest.fixture(autouse=True)
def _isolated_tune_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    yield tmp_path / "tune"
    clear_memory_cache()


# ----------------------------------------------------------------------
# DMA-ledger replay
# ----------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    ReplayCase("batch_p4", "batch", pbatch=4),
    ReplayCase("batch_p3", "batch", pbatch=3),       # remainder tail
    ReplayCase("batch_db_p4_d3", "batch_db", pbatch=4, depth=3),
    ReplayCase("single_db_d2", "single_db", depth=2),
    ReplayCase("batch_shared_p4", "batch_shared", pbatch=4),
    ReplayCase("batch_int8_p4", "batch", pbatch=4, quantized=True),
], ids=lambda c: c.name)
def test_ledger_clean_on_real_kernels(case):
    """The repo kernels replay with balanced ledgers at the promised
    pipeline depth."""
    ledger = replay(case)
    assert ledger.raw_findings == []
    assert ledger.issues == ledger.waits > 0
    assert ledger.max_in_flight == case.promised


def test_ledger_flags_unbalanced_fixture():
    findings, ledger = replay_fixture(
        str(FIXTURES / "bad_ledger_kernel.py"))
    rules = {f.rule for f in findings}
    assert "unwaited-dma" in rules
    assert ledger.issues > ledger.waits


def test_ledger_flags_wait_before_issue():
    """A kernel that waits on a semaphore nobody signalled is flagged."""
    import numpy as np

    import jax.numpy as jnp  # noqa: F401

    def kernel(A_ref, img_ref, vol_in_ref, vol_out_ref, strip_ref, sem,
               *, o_mm, n_u, n_v, ty, chunk, band, width,
               quantized=False):
        import repro.kernels.backproject as K

        K.pltpu.make_async_copy(
            img_ref.at[K.pl.ds(0, band), K.pl.ds(0, width)],
            strip_ref, sem).wait()
        vol_out_ref[...] = np.asarray(vol_in_ref[...])

    ledger = replay(ReplayCase("waits-first", "single"),
                    kernel_fn=kernel)
    assert {"wait-before-issue"} == {r for r, _ in ledger.raw_findings}


# ----------------------------------------------------------------------
# VMEM budget model — one implementation behind the tuner screen
# ----------------------------------------------------------------------

def test_fits_vmem_delegates_to_budget_model(monkeypatch):
    """``pallas_batch_fits_vmem`` is the budget model — patch the model
    and the tuner screen follows."""
    import repro.tune.space as space

    params = dict(pbatch=4, ty=8, chunk=16, band=16, width=128)
    assert space.pallas_batch_fits_vmem(GS, **params)

    class _Never:
        fits = False

    monkeypatch.setattr(space, "batch_vmem_estimate",
                        lambda *a, **k: _Never())
    assert not space.pallas_batch_fits_vmem(GS, **params)


def test_fits_vmem_equals_model_across_grid():
    for pbatch in (1, 4, 16):
        for depth in (2, 4):
            for itemsize in (4, 2, 1):
                for band, width in ((16, 128), (968, 1280)):
                    got = pallas_batch_fits_vmem(
                        GS, pbatch=pbatch, ty=8, chunk=32, band=band,
                        width=width, depth=depth, itemsize=itemsize)
                    est = batch_vmem_estimate(
                        GS, pbatch=pbatch, ty=8, chunk=32, band=band,
                        width=width, depth=depth, itemsize=itemsize)
                    assert got == est.fits
                    assert est.budget == VMEM_BUDGET_BYTES


def test_budget_sublane_table_matches_kernel_ops():
    from repro.analysis.lint import budget as budget_mod
    from repro.kernels import backproject_ops

    assert budget_mod._SUBLANE == backproject_ops._SUBLANE


def test_budget_int8_counts_scale_sideband():
    """The 1-byte wire carries a (P, 2, rows) f32 sideband at
    sublane-32 padded rows; wider wires carry none."""
    kw = dict(pbatch=4, ty=8, chunk=16, band=16, width=128)
    f32 = batch_vmem_estimate(GS, itemsize=4, **kw)
    int8 = batch_vmem_estimate(GS, itemsize=1, **kw)
    assert f32.scale_bytes == 0
    rows = max(16, GS.n_v + 2)             # 32, already 32-aligned
    rows += (-rows) % 32
    assert int8.scale_bytes == 4 * 2 * rows * 4
    assert int8.strip_bytes == f32.strip_bytes // 4


def test_budget_screens_candidate_generator():
    from repro.analysis.lint.budget import screen_candidate_spaces

    findings, checked = screen_candidate_spaces()
    assert findings == [] and checked > 0


# ----------------------------------------------------------------------
# Trace hygiene
# ----------------------------------------------------------------------

def _rules(src):
    return [f.rule for f in check_source("<t>", textwrap.dedent(src))]


def test_hygiene_flags_jit_in_fn():
    assert _rules("""
        import jax
        def hot(x):
            return jax.jit(lambda y: y + 1)(x)
        """) == ["jit-in-fn"]


def test_hygiene_allows_self_assigned_and_module_jit():
    assert _rules("""
        import jax
        step = jax.jit(lambda y: y)
        class Engine:
            def __init__(self):
                self._step = jax.jit(lambda y: y + 1)
        """) == []


def test_hygiene_pragma_suppresses():
    assert _rules("""
        import jax
        def once(step):
            return jax.jit(step)  # lint: ok(jit-in-fn)
        """) == []


def test_hygiene_flags_warn_without_stacklevel():
    assert _rules("""
        import warnings
        def f():
            warnings.warn("boom", RuntimeWarning)
        """) == ["warn-stacklevel"]
    assert _rules("""
        import warnings
        def f():
            warnings.warn("boom", RuntimeWarning, stacklevel=2)
        """) == []


def test_hygiene_flags_mutable_default():
    assert _rules("""
        def f(x, opts={}):
            return opts
        """) == ["mutable-default"]


def test_hygiene_flags_nonhashable_static():
    found = _rules("""
        import jax
        from functools import partial
        @partial(jax.jit, static_argnames=("opts",))
        def f(x, opts={}):
            return x
        """)
    assert "nonhashable-static" in found


def test_hygiene_flags_unused_import():
    assert _rules("""
        import os
        import numpy as np
        from typing import Sequence
        def f(x):
            return np.asarray(x)
        """) == ["unused-import", "unused-import"]


def test_hygiene_unused_import_exemptions():
    """__all__ re-exports, redundant aliases, noqa, __future__, and the
    pragma are all deliberate — none is a finding."""
    assert _rules("""
        from __future__ import annotations
        from .core import reconstruct, Geometry
        from .tune import autotune as autotune
        import repro.kernels  # noqa: F401 (side-effect registration)
        import repro.serving  # lint: ok(unused-import)
        __all__ = ["reconstruct", "Geometry"]
        """) == []


def test_hygiene_unused_import_sees_attribute_roots():
    """``import a.b`` binds ``a``; use through ``a.b.c`` counts."""
    assert _rules("""
        import os.path
        def f(p):
            return os.path.join(p, "x")
        """) == []
    assert _rules("""
        import os.path
        """) == ["unused-import"]


def test_hygiene_clean_tree_is_the_false_positive_gate():
    res = run_hygiene_pass(str(REPO / "src"))
    assert res.findings == []
    assert res.checked > 50


# ----------------------------------------------------------------------
# Tuned-cache audit
# ----------------------------------------------------------------------

def test_parse_cache_key_roundtrip():
    from repro.tune.cache import cache_key

    key = cache_key(GS, "cpu", "cpu")
    parsed = parse_cache_key(key)
    assert parsed is not None
    gs, backend, device = parsed
    assert gs == GS and backend == "cpu" and device == "cpu"
    assert parse_cache_key("not-a-cache-key") is None
    assert geometry_for(gs) is not None


def test_audit_flags_overflow_fixture():
    findings = audit_cache_file(
        FIXTURES / "overflow_tune"
        / "ct-L16-u39-v30-O-120-MM16--cpu--cpu.json")
    assert [f.rule for f in findings] == ["planner-invalid"]
    assert "VMEM budget" in findings[0].detail


def test_audit_flags_stale_fixture():
    findings = audit_cache_file(
        FIXTURES / "stale_tune"
        / "ct-L16-u39-v30-O-120-MM16--cpu--cpu.json")
    assert [f.rule for f in findings] == ["stale-schema"]


def test_audit_flags_undersized_window_via_planner():
    cfg = TunedConfig(strategy="strip2",
                      opts={"group": 8, "gband": 2, "gwidth": 8,
                            "pbatch": 4},
                      backend="cpu", device_kind="cpu", us_per_call=1.0)
    reasons = audit_tuned_config(GS, cfg, geom=GEOM)
    assert any("planner" in r for r in reasons)


def test_audit_clean_config_has_no_reasons():
    cfg = TunedConfig(strategy="strip2",
                      opts={"group": 8, "gband": 32, "gwidth": 41,
                            "pbatch": 4},
                      backend="cpu", device_kind="cpu", us_per_call=1.0,
                      pallas={"ty": 8, "chunk": 16, "band": 32,
                              "width": 128, "pbatch": 4})
    assert audit_tuned_config(GS, cfg, geom=GEOM) == []


def test_audit_pass_flags_corrupt_and_misnamed(tmp_path):
    d = tmp_path / "tune"
    d.mkdir()
    (d / "ct-L16-u39-v30-O-120-MM16--cpu--cpu.json").write_text("{nope")
    (d / "leftover.json").write_text("{}")
    res = run_cache_audit_pass(d)
    assert sorted(f.rule for f in res.findings) == ["corrupt-file",
                                                    "unparseable-key"]
    assert res.checked == 2


def test_audit_pass_empty_dir_is_clean(tmp_path):
    res = run_cache_audit_pass(tmp_path / "nothing-here")
    assert res.findings == [] and res.checked == 0 and res.notes


# ----------------------------------------------------------------------
# Dispatcher wiring: stale cached config -> warn once + re-select
# ----------------------------------------------------------------------

def test_dispatcher_rejects_planner_invalid_cache(caplog):
    from repro.dispatch import Dispatcher
    from repro.tune.sweep import SweepResult, Timing

    bad = TunedConfig(
        strategy="strip2",
        opts={"group": 8, "gband": 32, "gwidth": 41, "pbatch": 4},
        backend="cpu", device_kind="cpu", us_per_call=1.0,
        pallas={"ty": 8, "chunk": 16, "band": 32, "width": 128,
                "pbatch": 1024})        # over the VMEM budget
    store_tuned(GS, bad)

    def fake_sweep(geom, **kw):
        return SweepResult(
            geom_key=tuple(GS), backend="cpu", device_kind="cpu",
            timings=[Timing(label="gather[pbatch=4]", strategy="gather",
                            opts=(("pbatch", 4),), us_per_call=9.0,
                            gups=1.0)],
            skipped=[])

    d = Dispatcher(insitu=True, sweep_fn=fake_sweep, backend="cpu",
                   device_kind="cpu")
    with caplog.at_level(logging.WARNING, logger="repro.dispatch"):
        plan = d.resolve(GEOM)
        d.resolve(GEOM)
    audit_warnings = [r for r in caplog.records
                      if "fails the current planner" in r.getMessage()]
    assert len(audit_warnings) == 1       # one structured warning
    msg = audit_warnings[0].getMessage()
    assert "ct-L16-u39-v30-O-120-MM16--cpu--cpu" in msg   # names the key
    assert ".json" in msg                                 # ...and file
    assert "VMEM budget" in msg                           # ...and reason
    # Resolution fell back to in-situ selection, not the stale window.
    assert plan.strategy == "gather"


def test_dispatcher_accepts_planner_valid_cache(caplog):
    from repro.dispatch import Dispatcher

    good = TunedConfig(
        strategy="strip2",
        opts={"group": 8, "gband": 32, "gwidth": 41, "pbatch": 4},
        backend="cpu", device_kind="cpu", us_per_call=1.0)
    store_tuned(GS, good)
    d = Dispatcher(insitu=False, backend="cpu", device_kind="cpu")
    with caplog.at_level(logging.WARNING, logger="repro.dispatch"):
        plan = d.resolve(GEOM)
    assert plan.strategy == "strip2"
    assert not [r for r in caplog.records
                if "fails the current planner" in r.getMessage()]


# ----------------------------------------------------------------------
# Runtime retrace counter
# ----------------------------------------------------------------------

def test_retrace_counter_one_compile_per_plan(retrace_counter):
    from repro.core import backproject as core_bp
    from repro.core.phantom import make_dataset

    geom = Geometry().scaled(16, n_proj=7)   # shape unique to this test
    projs, mats, _ = make_dataset(geom)
    counter = retrace_counter(core_bp._reconstruct_jit)
    reconstruct(projs, mats, geom, strategy="strip2")
    first = counter.delta()
    assert first == 1                  # one plan, one compile
    reconstruct(projs, mats, geom, strategy="strip2")
    assert counter.delta() == first    # same plan: zero retraces


# ----------------------------------------------------------------------
# CLI exit codes (subprocess)
# ----------------------------------------------------------------------

def _run_cli(*args, tmp_json=None):
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=str(REPO / "src"))
    env.pop("REPRO_TUNE_DIR", None)
    cmd = [sys.executable, "-m", "repro.analysis.lint", *args]
    if tmp_json is not None:
        cmd += ["--json", str(tmp_json)]
    proc = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=600)
    report = json.loads(proc.stdout)
    return proc.returncode, report


def test_cli_nonzero_on_bad_ledger_fixture():
    code, report = _run_cli(
        "--passes", "ledger",
        "--kernel-fixture", str(FIXTURES / "bad_ledger_kernel.py"))
    assert code == 1 and not report["ok"]
    assert any(f["rule"] == "unwaited-dma" for f in report["findings"])


def test_cli_nonzero_on_overflow_fixture():
    code, report = _run_cli("--passes", "cache", "--tune-dir",
                            str(FIXTURES / "overflow_tune"))
    assert code == 1 and not report["ok"]
    assert any(f["rule"] == "planner-invalid"
               for f in report["findings"])


def test_cli_nonzero_on_stale_fixture():
    code, report = _run_cli("--passes", "cache", "--tune-dir",
                            str(FIXTURES / "stale_tune"))
    assert code == 1 and not report["ok"]
    assert any(f["rule"] == "stale-schema" for f in report["findings"])


def test_cli_clean_tree_exits_zero(tmp_path):
    """Acceptance: the full checker on the clean tree — zero findings,
    exit 0, and every pass actually checked something."""
    code, report = _run_cli("--fail-on-findings",
                            tmp_json=tmp_path / "lint.json")
    assert code == 0
    assert report["ok"] and report["findings"] == []
    by_name = {p["pass"]: p for p in report["passes"]}
    assert set(by_name) == {"ledger", "budget", "hygiene", "cache"}
    for name in ("ledger", "budget", "hygiene"):
        assert by_name[name]["checked"] > 0, f"{name} pass was vacuous"
    assert json.loads((tmp_path / "lint.json").read_text()) == report
