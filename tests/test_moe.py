"""MoE dispatch properties: impl equivalence, conservation, capacity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.configs.base import ModelConfig
from repro.models.layers import Param
from repro.models.moe import init_moe, moe_capacity, moe_forward


def _cfg(E=4, k=2, d=32, ff=16, cf=8.0):
    return ModelConfig(name="t", family="moe", n_layers=2, d_model=d,
                       n_heads=4, n_kv_heads=2, d_ff=0, vocab=64,
                       moe=True, n_experts=E, top_k=k, moe_d_ff=ff,
                       capacity_factor=cf, param_dtype="float32")


def _init(cfg, seed=0):
    p = Param(jax.random.PRNGKey(seed), jnp.float32)
    init_moe(p, cfg)
    return p.params


@given(seed=st.integers(0, 20), B=st.sampled_from([1, 2]),
       S=st.sampled_from([4, 16]))
@settings(max_examples=15, deadline=None)
def test_scatter_equals_einsum(seed, B, S):
    """The two dispatch implementations are numerically identical."""
    cfg = _cfg()
    params = _init(cfg, seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100),
                          (B, S, cfg.d_model), jnp.float32)
    y1, a1 = moe_forward(params, cfg, x, impl="scatter",
                         dtype=jnp.float32)
    y2, a2 = moe_forward(params, cfg, x, impl="einsum",
                         dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_capacity_drops_tokens():
    """With capacity_factor ~0, every token drops -> output is zero."""
    cfg = dataclasses.replace(_cfg(), capacity_factor=1e-9)
    # capacity floors at 8; use many tokens so most drop
    params = _init(cfg)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64, cfg.d_model))
    y, _ = moe_forward(params, cfg, x, impl="scatter", dtype=jnp.float32)
    # at most E*C tokens got routed; the rest must be exactly zero
    zero_rows = np.sum(np.all(np.asarray(y) == 0.0, axis=-1))
    assert zero_rows >= 4 * 64 - cfg.n_experts * moe_capacity(cfg, 256)


def test_aux_loss_uniform_router_is_one():
    """Balanced routing gives aux loss ~= 1 (Switch normalisation)."""
    cfg = _cfg(E=8, k=1)
    params = _init(cfg)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])  # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 256, cfg.d_model))
    _, aux = moe_forward(params, cfg, x, impl="scatter",
                         dtype=jnp.float32)
    # f_e from argmax ties is not perfectly uniform, but P_e is exactly
    # 1/E, so aux = E * sum_e f_e / E = 1.
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-3)


def test_moe_grads_flow_to_all_param_kinds():
    cfg = _cfg()
    params = _init(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))

    def loss(p):
        y, aux = moe_forward(p, cfg, x, impl="scatter",
                             dtype=jnp.float32)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for name, arr in g.items():
        assert float(jnp.max(jnp.abs(arr))) > 0, f"dead grads: {name}"
