"""Strategy-equivalence property tests for the back projection kernel.

Every strategy implements identical semantics (floor bilinear, zero
outside the detector, 1/w^2 weight) — pairwise allclose vs the scalar
oracle across geometry sweeps, plus end-to-end reconstruction agreement.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import Geometry, filter_projections
from repro.core.backproject import (GeomStatic, STRATEGIES, _pad_image,
                                    backproject_one, plane_coords,
                                    sample_gather, sample_onehot,
                                    sample_scalar, sample_strip,
                                    sample_strip2)
from repro.core.geometry import projection_matrix
from repro.core.phantom import make_dataset

GEOM = Geometry().scaled(16, n_proj=8)
GS = GeomStatic.of(GEOM)
_DS = make_dataset(GEOM)


def _plane_vals(theta, z, fn, **kw):
    A = jnp.asarray(projection_matrix(GEOM, theta), jnp.float32)
    image = jnp.asarray(_DS[0][0])
    ix, iy, w = plane_coords(A, GS, jnp.int32(z))
    if fn is sample_scalar:
        return np.asarray(fn(image, ix, iy, GS))
    return np.asarray(fn(_pad_image(image), ix, iy, GS, **kw))


@given(theta=st.floats(0.0, 6.28), z=st.integers(0, GEOM.L - 1))
@settings(max_examples=20, deadline=None)
def test_gather_matches_scalar(theta, z):
    a = _plane_vals(theta, z, sample_scalar)
    b = _plane_vals(theta, z, sample_gather)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@given(theta=st.floats(0.0, 6.28), z=st.integers(0, GEOM.L - 1))
@settings(max_examples=10, deadline=None)
def test_onehot_matches_scalar(theta, z):
    a = _plane_vals(theta, z, sample_scalar)
    b = _plane_vals(theta, z, sample_onehot, vox_block=64)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@given(theta=st.floats(0.0, 6.28), z=st.integers(0, GEOM.L - 1),
       chunk=st.sampled_from([8, 16]))
@settings(max_examples=20, deadline=None)
def test_strip_matches_scalar(theta, z, chunk):
    a = _plane_vals(theta, z, sample_scalar)
    b = _plane_vals(theta, z, sample_strip, chunk=chunk, band=16,
                    width=128, strips_per_block=GEOM.L // chunk)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@given(theta=st.floats(0.0, 6.28), z=st.integers(0, GEOM.L - 1))
@settings(max_examples=20, deadline=None)
def test_strip2_matches_scalar(theta, z):
    a = _plane_vals(theta, z, sample_scalar)
    b = _plane_vals(theta, z, sample_strip2, group=8, gband=8, gwidth=64)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("strategy,opts", [
    ("gather", {}),
    ("onehot", {"vox_block": 64}),
    ("strip", {"chunk": 16, "band": 16, "width": 128}),
    ("strip2", {"group": 8, "gband": 8, "gwidth": 64}),
])
def test_full_volume_agreement(strategy, opts):
    projs, mats, _ = _DS
    filt = filter_projections(projs[:2], GEOM, angle_indices=np.arange(2))
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    ref = backproject_one(vol0, filt[0], mats[0], GEOM, strategy="scalar")
    out = backproject_one(vol0, filt[0], mats[0], GEOM,
                          strategy=strategy, **opts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_reciprocal_weighting_masks_nonpositive_w():
    """w <= 0 voxels contribute exactly zero (accumulate contract)."""
    from repro.core.backproject import accumulate
    plane = jnp.zeros((4, 4), jnp.float32)
    val = jnp.ones((4, 4), jnp.float32)
    w = jnp.asarray([[1.0, 0.5, 0.0, -1.0]] * 4, jnp.float32)
    out = np.asarray(accumulate(plane, val, w))
    assert out[0, 0] == pytest.approx(1.0)
    assert out[0, 1] == pytest.approx(4.0)
    assert out[0, 2] == 0.0 and out[0, 3] == 0.0


def test_bilinear_exact_on_linear_image():
    """Bilinear interp reproduces a linear ramp exactly (property)."""
    ramp = (jnp.arange(GEOM.n_v)[:, None] * 2.0
            + jnp.arange(GEOM.n_u)[None, :] * 3.0).astype(jnp.float32)
    A = jnp.asarray(projection_matrix(GEOM, 0.3), jnp.float32)
    ix, iy, w = plane_coords(A, GS, jnp.int32(GEOM.L // 2))
    vals = np.asarray(sample_scalar(ramp, ix, iy, GS))
    ixn = np.asarray(ix)
    iyn = np.asarray(iy)
    interior = (ixn >= 0) & (ixn <= GEOM.n_u - 1) & (iyn >= 0) \
        & (iyn <= GEOM.n_v - 1)
    expect = iyn * 2.0 + ixn * 3.0
    np.testing.assert_allclose(vals[interior], expect[interior],
                               rtol=1e-4, atol=1e-3)
