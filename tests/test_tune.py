"""Autotuner subsystem: sweep, cache, strategy="auto", jit-cache stability.

Every test isolates the on-disk cache in a tmp dir (``REPRO_TUNE_DIR``)
and drops the in-process memo, so decisions never leak between tests or
from a developer's ``.repro_tune/``.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Geometry, filter_projections, reconstruct
from repro.core.backproject import (GeomStatic, STRATEGIES,
                                    _reconstruct_jit)
from repro.core.phantom import make_dataset
from repro.kernels.backproject_ops import pallas_backproject_one
from repro.tune import (Candidate, TunedConfig, autotune, clear_memory_cache,
                        device_identity, load_tuned, store_tuned,
                        sweep_strategies)

GEOM = Geometry().scaled(16, n_proj=4)
GS = GeomStatic.of(GEOM)


@pytest.fixture(autouse=True)
def _isolated_tune_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    yield
    clear_memory_cache()


@pytest.fixture(scope="module")
def ct_case():
    projs, mats, _ = make_dataset(GEOM)
    filt = np.asarray(filter_projections(projs, GEOM))
    return filt, mats


def test_auto_untuned_matches_strip2_bitwise(ct_case):
    """Acceptance: untuned auto == strip2 defaults, bit for bit."""
    filt, mats = ct_case
    a = np.asarray(reconstruct(filt, mats, GEOM, strategy="auto"))
    b = np.asarray(reconstruct(filt, mats, GEOM, strategy="strip2"))
    np.testing.assert_array_equal(a, b)


def test_auto_follows_tuned_cache(ct_case):
    """A stored decision redirects auto (bitwise vs the explicit call)."""
    filt, mats = ct_case
    backend, device_kind = device_identity()
    cfg = TunedConfig(strategy="gather", opts={}, backend=backend,
                      device_kind=device_kind, us_per_call=1.0)
    store_tuned(GS, cfg)
    a = np.asarray(reconstruct(filt, mats, GEOM, strategy="auto"))
    b = np.asarray(reconstruct(filt, mats, GEOM, strategy="gather"))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(reconstruct(filt, mats, GEOM, strategy="strip2"))
    assert not np.array_equal(a, c)


def test_auto_filters_mismatched_caller_opts(ct_case):
    """Options written for the fallback strategy must not crash when the
    cache tuned a different one (sample_onehot(gband=...) TypeError) —
    and the shed is *loud*: a RuntimeWarning names the dropped key."""
    filt, mats = ct_case
    backend, device_kind = device_identity()
    cfg = TunedConfig(strategy="onehot", opts={"vox_block": 64},
                      backend=backend, device_kind=device_kind,
                      us_per_call=1.0)
    store_tuned(GS, cfg)
    with pytest.warns(RuntimeWarning, match="gband"):
        a = np.asarray(reconstruct(filt, mats, GEOM, strategy="auto",
                                   gband=8))
    b = np.asarray(reconstruct(filt, mats, GEOM, strategy="onehot",
                               vox_block=64))
    np.testing.assert_array_equal(a, b)


def test_unknown_caller_opt_raises(ct_case):
    """A typo'd option is an error, not a silent no-op."""
    filt, mats = ct_case
    with pytest.raises(ValueError, match="unknown option"):
        reconstruct(filt, mats, GEOM, strategy="strip2", gbnad=8)


def test_autotune_sweeps_and_persists_roundtrip():
    cfg = autotune(GEOM, include_pallas=False, warmup=0, iters=1)
    assert cfg.strategy in STRATEGIES
    assert cfg.us_per_call > 0
    # Every timed candidate carries comparable numbers.
    assert len(cfg.timings) >= 5
    assert all(t["us_per_call"] > 0 and t["gups"] > 0
               for t in cfg.timings)
    clear_memory_cache()                      # force the disk path
    back = load_tuned(GS)
    assert back is not None
    assert (back.strategy, back.opts) == (cfg.strategy, cfg.opts)


def test_sweep_skips_undersized_windows():
    """A candidate the planner rejects is skipped, never timed."""
    bad = Candidate.of("strip2", group=8, gband=2, gwidth=8)
    ok = Candidate.of("gather")
    res = sweep_strategies(GEOM, space=[bad, ok], include_pallas=False,
                           warmup=0, iters=1)
    assert [t.strategy for t in res.timings] == ["gather"]
    assert len(res.skipped) == 1
    assert "does not cover" in res.skipped[0][1]


def test_stale_schema_versions_are_ignored(tmp_path):
    """A ``.repro_tune/`` file from an older schema (no ``version``, or a
    mismatched one) must be treated as untuned — never misread into the
    new dataclass (a v1 decision timed a loop nest that no longer
    exists)."""
    import os
    from pathlib import Path

    from repro.tune import TUNE_SCHEMA_VERSION, cache_key, load_tuned

    d = Path(os.environ["REPRO_TUNE_DIR"])
    d.mkdir(parents=True, exist_ok=True)
    backend, device_kind = device_identity()
    key = cache_key(GS, backend, device_kind)

    # v1-era file: no version field at all.
    v1 = {"strategy": "gather", "opts": {}, "backend": backend,
          "device_kind": device_kind, "us_per_call": 1.0}
    (d / f"{key}.json").write_text(json.dumps(v1))
    assert load_tuned(GS) is None

    # Future/mismatched version.
    v1["version"] = TUNE_SCHEMA_VERSION + 1
    (d / f"{key}.json").write_text(json.dumps(v1))
    clear_memory_cache()
    assert load_tuned(GS) is None

    # Current version loads.
    v1["version"] = TUNE_SCHEMA_VERSION
    (d / f"{key}.json").write_text(json.dumps(v1))
    clear_memory_cache()
    cfg = load_tuned(GS)
    assert cfg is not None and cfg.strategy == "gather"


def test_autotune_persists_current_version_and_pbatch():
    cfg = autotune(GEOM, include_pallas=False, warmup=0, iters=1)
    from repro.tune import TUNE_SCHEMA_VERSION

    assert cfg.version == TUNE_SCHEMA_VERSION
    # Every jnp candidate carries the pbatch axis now; the winner's
    # depth is what reconstruct(strategy="auto") will run.
    assert "pbatch" in cfg.opts and cfg.pbatch >= 1
    assert any(t["opts"].get("pbatch", 1) > 1 for t in cfg.timings)


def test_cache_file_is_json_keyed_on_device(tmp_path, monkeypatch):
    import jax
    cfg = autotune(GEOM, include_pallas=False, warmup=0, iters=1)
    files = list((tmp_path / "tune").glob("*.json"))
    assert len(files) == 1
    name = files[0].name
    assert f"L{GEOM.L}" in name and jax.default_backend() in name
    data = json.loads(files[0].read_text())
    assert data["strategy"] == cfg.strategy


def test_reconstruct_jit_cache_is_stable(ct_case):
    """Repeated reconstruct() calls must not recompile (the old inline
    ``@jax.jit`` closure recompiled on every invocation)."""
    filt, mats = ct_case
    reconstruct(filt, mats, GEOM, strategy="gather")
    size_after_first = _reconstruct_jit._cache_size()
    for _ in range(3):
        reconstruct(filt, mats, GEOM, strategy="gather")
    assert _reconstruct_jit._cache_size() == size_after_first


def test_pallas_auto_uses_tuned_tiles(ct_case):
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    img, A = jnp.asarray(filt[0]), jnp.asarray(mats[0])

    # Untuned: auto falls back to the passed parameters.
    out_auto = pallas_backproject_one(vol0, img, A, GEOM, ty=4, chunk=8,
                                      band=16, width=128, strategy="auto")
    out_fix = pallas_backproject_one(vol0, img, A, GEOM, ty=4, chunk=8,
                                     band=16, width=128)
    np.testing.assert_array_equal(np.asarray(out_auto), np.asarray(out_fix))

    # Tuned: auto picks the cached tile config (micro variant here).
    backend, device_kind = device_identity()
    cfg = TunedConfig(strategy="strip2", opts={}, backend=backend,
                      device_kind=device_kind, us_per_call=1.0,
                      pallas={"ty": 8, "chunk": 16, "band": 16,
                              "width": 128, "micro": True})
    store_tuned(GS, cfg)
    out_auto = pallas_backproject_one(vol0, img, A, GEOM, strategy="auto")
    out_fix = pallas_backproject_one(vol0, img, A, GEOM, ty=8, chunk=16,
                                     band=16, width=128, micro=True)
    np.testing.assert_array_equal(np.asarray(out_auto), np.asarray(out_fix))

    with pytest.raises(ValueError, match="fixed.*auto|auto.*fixed"):
        pallas_backproject_one(vol0, img, A, GEOM, strategy="strip")


def test_pallas_auto_resolves_full_micro_window(ct_case):
    """A tuned ``micro=True`` decision carries its validated
    ``(micro_group, micro_band, micro_width)`` window through the cache
    — auto used to resolve the flag but run default windows the sweep
    never validated."""
    from repro.tune import resolve_pallas_config
    from repro.tune.space import pallas_candidates

    # The swept micro candidate names its window explicitly, so the
    # timed/validated values are the persisted values.
    micro_cands = [c for c in pallas_candidates(GS)
                   if dict(c.opts).get("micro")]
    assert micro_cands
    for c in micro_cands:
        opts = dict(c.opts)
        assert {"micro_group", "micro_band", "micro_width"} <= set(opts)

    backend, device_kind = device_identity()
    tuned_win = {"micro_group": 8, "micro_band": 12, "micro_width": 64}
    cfg = TunedConfig(strategy="strip2", opts={}, backend=backend,
                      device_kind=device_kind, us_per_call=1.0,
                      pallas={"ty": 8, "chunk": 16, "band": 16,
                              "width": 128, "micro": True, **tuned_win})
    store_tuned(GS, cfg)
    resolved = resolve_pallas_config(GS)
    for k, v in tuned_win.items():
        assert resolved[k] == v

    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    img, A = jnp.asarray(filt[0]), jnp.asarray(mats[0])
    out_auto = pallas_backproject_one(vol0, img, A, GEOM, strategy="auto")
    out_fix = pallas_backproject_one(vol0, img, A, GEOM, ty=8, chunk=16,
                                     band=16, width=128, micro=True,
                                     **tuned_win)
    np.testing.assert_array_equal(np.asarray(out_auto),
                                  np.asarray(out_fix))


def test_pallas_batch_auto_honors_tuned_variant_flags(ct_case):
    """The batch path runs the kernel a tuned decision was timed on:
    ``double_buffer``/``db_depth`` resolve to the pipelined batch
    variant — bitwise against the explicit call, with no shed-the-flag
    warning left anywhere (warnings are errors here)."""
    import warnings

    from repro.kernels.backproject_ops import pallas_backproject_batch

    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    backend, device_kind = device_identity()
    cfg = TunedConfig(strategy="strip2", opts={}, backend=backend,
                      device_kind=device_kind, us_per_call=1.0,
                      pallas={"ty": 8, "chunk": 16, "band": 16,
                              "width": 128, "double_buffer": True,
                              "db_depth": 3, "pbatch": 2})
    store_tuned(GS, cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        out = pallas_backproject_batch(vol0, filt, mats, GEOM,
                                       strategy="auto")
    ref = pallas_backproject_batch(vol0, filt, mats, GEOM, ty=8, chunk=16,
                                   band=16, width=128, pbatch=2,
                                   double_buffer=True, db_depth=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_pallas_one_auto_resolves_tuned_db_depth(ct_case):
    """The single-projection path resolves ``db_depth`` with the
    ``double_buffer`` flag (the depth is part of the timed pipeline
    shape, and both paths share one rotation ledger).  The result is
    schedule-invariant, so the honoring is proven through the depth
    validation: a tuned sub-2 depth reaches the kernel selection and
    raises there."""
    filt, mats = ct_case
    vol0 = jnp.zeros((GEOM.L,) * 3, jnp.float32)
    img, A = jnp.asarray(filt[0]), jnp.asarray(mats[0])
    backend, device_kind = device_identity()
    pallas = {"ty": 8, "chunk": 16, "band": 16, "width": 128,
              "double_buffer": True, "db_depth": 4}
    store_tuned(GS, TunedConfig(strategy="strip2", opts={},
                                backend=backend, device_kind=device_kind,
                                us_per_call=1.0, pallas=pallas))
    out_auto = pallas_backproject_one(vol0, img, A, GEOM, strategy="auto")
    out_fix = pallas_backproject_one(vol0, img, A, GEOM, ty=8, chunk=16,
                                     band=16, width=128,
                                     double_buffer=True, db_depth=4)
    np.testing.assert_array_equal(np.asarray(out_auto),
                                  np.asarray(out_fix))

    clear_memory_cache()
    store_tuned(GS, TunedConfig(strategy="strip2", opts={},
                                backend=backend, device_kind=device_kind,
                                us_per_call=1.0,
                                pallas={**pallas, "db_depth": 1}))
    with pytest.raises(ValueError, match="db_depth"):
        pallas_backproject_one(vol0, img, A, GEOM, strategy="auto")


def test_pallas_batch_candidates_cross_variants():
    """The batched candidate family spans pbatch × {plain, db, micro},
    every variant-bearing candidate naming its full surface (db_depth /
    micro window) so the timed values are the persisted values, and
    deep-rotation candidates pass the depth-aware VMEM check."""
    from repro.tune.space import pallas_batch_fits_vmem, pallas_candidates

    cands = [dict(c.opts) for c in pallas_candidates(GS)]
    batched = [c for c in cands if c.get("pbatch", 1) > 1]
    assert any(c.get("double_buffer") for c in batched)
    assert any(c.get("micro") for c in batched)
    assert any(not c.get("double_buffer") and not c.get("micro")
               for c in batched)
    for c in batched:
        if c.get("double_buffer"):
            assert c["db_depth"] >= 2
            assert pallas_batch_fits_vmem(
                GS, pbatch=c["pbatch"], ty=c["ty"], chunk=c["chunk"],
                band=c["band"], width=c["width"], depth=c["db_depth"])
        if c.get("micro"):
            assert {"micro_group", "micro_band", "micro_width"} <= set(c)
        assert not (c.get("double_buffer") and c.get("micro"))


def test_candidate_space_spans_new_axes():
    """The v4 axes compete: the jnp family proposes a bf16-wire strip2,
    the kernel family proposes bf16 and shared-window batch variants,
    and no candidate combines the shared slab with db/micro."""
    from repro.tune.space import jnp_candidates, pallas_candidates

    jnp_opts = [dict(c.opts) for c in jnp_candidates(GS)]
    assert any(c.get("strip_dtype") == "bfloat16" for c in jnp_opts)
    cands = [dict(c.opts) for c in pallas_candidates(GS)]
    assert any(c.get("strip_dtype") == "bfloat16"
               and not c.get("shared_window") for c in cands)
    shared = [c for c in cands if c.get("shared_window")]
    assert shared and any(c.get("strip_dtype") == "bfloat16"
                          for c in shared)
    for c in shared:
        assert not c.get("double_buffer") and not c.get("micro")


def test_sweep_times_or_skips_shared_and_bf16():
    """A sweep over the new axes either times each candidate or skips it
    with a recorded reason — never crashes, never times an invalid
    config (the VMEM screen re-runs at the planner-tight shared dims)."""
    from repro.tune.space import Candidate
    from repro.tune.sweep import sweep_strategies

    geom = Geometry().scaled(16, n_proj=4)
    space = [
        Candidate.of("strip2", group=8, gband=8, gwidth=64,
                     strip_dtype="bfloat16", pbatch=2),
        Candidate.of("pallas", ty=8, chunk=16, band=16, width=128,
                     pbatch=2, strip_dtype="bfloat16"),
        Candidate.of("pallas", ty=8, chunk=16, band=16, width=128,
                     pbatch=2, shared_window=True),
        Candidate.of("pallas", ty=8, chunk=16, band=16, width=128,
                     pbatch=2, shared_window=True,
                     strip_dtype="bfloat16"),
    ]
    res = sweep_strategies(geom, space=space, include_pallas=True,
                           warmup=0, iters=1, min_total_s=0)
    assert len(res.timings) + len(res.skipped) == len(space)
    timed = {t.label for t in res.timings}
    assert any("strip2" in lbl for lbl in timed)
    for lbl, reason in res.skipped:
        assert reason


def test_resolve_strategy_passes_strip_dtype(tmp_path, monkeypatch):
    """``strip_dtype`` survives auto resolution for the strip families —
    a tuned bf16 decision must actually run bf16."""
    from repro.tune.cache import resolve_strategy

    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path / "tune"))
    clear_memory_cache()
    backend, device_kind = device_identity()
    cfg = TunedConfig(strategy="strip2",
                      opts={"strip_dtype": "bfloat16", "pbatch": 2},
                      backend=backend, device_kind=device_kind,
                      us_per_call=1.0)
    store_tuned(GS, cfg)
    strategy, opts = resolve_strategy(GS)
    clear_memory_cache()
    assert strategy == "strip2"
    assert opts["strip_dtype"] == "bfloat16"


def test_sharded_reconstruct_auto(ct_case):
    """auto resolves host-side before shard_map (1x1 mesh, bitwise)."""
    from repro.core.pipeline import sharded_reconstruct
    from repro.launch.mesh import make_local_mesh

    filt, mats = ct_case
    mesh = make_local_mesh(data=1, model=1)
    a = np.asarray(sharded_reconstruct(filt, mats, GEOM, mesh,
                                       strategy="auto"))
    b = np.asarray(reconstruct(filt, mats, GEOM, strategy="strip2"))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
