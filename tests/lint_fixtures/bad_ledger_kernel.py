"""Seeded known-bad kernel: the last projection's DMA is never awaited.

A batch-style kernel stub for the DMA-ledger replay
(``repro.analysis.lint.ledger.replay_fixture``): it issues one strip
copy per projection through the usual 2-slot rotation but only waits
while a *next* projection exists — the final copy of every grid step
leaks.  On hardware that is a semaphore left signalled into the next
grid step (and a slot overwritten while its copy is in flight); the
ledger must flag it (``unwaited-dma`` at finish, ``slot-overwrite`` /
``wait-descriptor-mismatch`` as later steps reuse the leaked slot).

``pl``/``pltpu``/``jax`` are module globals so the replay harness can
swap in its recording stubs; the module is never imported outside the
lint tests.
"""

import jax  # noqa: F401  (replaced by the replay harness)
import jax.numpy as jnp

pl = None      # patched to the recording stubs by the replay harness
pltpu = None

SPEC = {"name": "unbalanced-batch", "kind": "batch", "pbatch": 4}


def kernel(A_ref, imgs_ref, vol_in_ref, vol_out_ref, strip_ref, acc_ref,
           sems, *, o_mm, n_u, n_v, ty, chunk, band, width, pbatch,
           quantized=False):
    acc_ref[...] = vol_in_ref[0].astype(jnp.float32)

    def body(p, _):
        slot = jax.lax.rem(p, 2)
        copy = pltpu.make_async_copy(
            imgs_ref.at[p, pl.ds(0, band), pl.ds(0, width)],
            strip_ref.at[slot], sems.at[slot])
        copy.start()

        # BUG under test: the guard skips the wait for the final
        # projection, so its copy is never consumed.
        @pl.when(p + 1 < pbatch)
        def _():
            copy.wait()

        return 0

    jax.lax.fori_loop(0, pbatch, body, 0)
    vol_out_ref[...] = vol_in_ref[...]
