"""Train-step factory: loss + grad + AdamW under pjit.

``make_train_step`` builds the jittable ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` function every launcher and the dry-run
lower.  Gradient-accumulation microbatching and int8 gradient
compression (DP axis) are composable options; remat is per block-period
inside the model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import loss_fn

from .optim import AdamWConfig, adamw_update

__all__ = ["make_train_step", "make_eval_step"]


def make_train_step(cfg, opt_cfg: AdamWConfig, *, moe_impl="scatter",
                    remat=True, accum_steps: int = 1):
    """Returns ``train_step(params, opt_state, batch)``.

    ``accum_steps > 1`` splits the batch on axis 0 into microbatches and
    accumulates grads in fp32 (classic memory/throughput trade; the
    dry-run's hillclimbs sweep it).
    """

    def loss_of(p, b):
        return loss_fn(p, cfg, b, moe_impl=moe_impl, remat=remat)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulated(params, batch):
        def micro(b):
            return jax.tree.map(
                lambda x: x.reshape((accum_steps,
                                     x.shape[0] // accum_steps)
                                    + x.shape[1:]), b)

        mb = micro(batch)

        def body(carry, b):
            loss_acc, grads_acc = carry
            (loss, metrics), grads = grad_fn(params, b)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
            return (loss_acc + loss, grads_acc), metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), metrics = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), mb)
        grads = jax.tree.map(lambda g: g / accum_steps, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / accum_steps, metrics, grads

    def train_step(params, opt_state, batch):
        if accum_steps > 1:
            loss, metrics, grads = accumulated(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        params, opt_state, stats = adamw_update(grads, params, opt_state,
                                                opt_cfg)
        metrics = dict(metrics)
        metrics.update(stats)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg, *, moe_impl="scatter"):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, cfg, batch, moe_impl=moe_impl,
                                remat=False)
        return {"loss": loss, **metrics}

    return eval_step
