"""Training substrate: optimizer, train step, schedules."""

from .optim import AdamWConfig, adamw_update, init_opt_state  # noqa: F401
from .train import make_train_step  # noqa: F401
