"""AdamW with sharded, optionally quantized optimizer state.

No optax offline; this is a full implementation: bias-corrected AdamW,
decoupled weight decay, global-norm clipping, cosine schedule with
warmup, and a ``state_dtype`` knob:

``float32``   classic (16 bytes/param of optimizer state)
``bfloat16``  half-cost moments
``int8``      blockwise-quantized moments (per-last-axis-channel scales),
              ~2.06 bytes/param of state — the distributed-optimization
              trick that lets the 1T-param Kimi-K2 train cell fit 512
              v5e chips (EXPERIMENTS.md §Dry-run).  Quantisation error
              feeds back through the next update's re-quantisation, the
              same argument as 8-bit Adam (Dettmers et al.).

Optimizer state inherits each parameter's sharding (moments shard like
the param; int8 scales shard like the param minus its last axis), so
ZeRO-style partitioning falls out of the same logical-axis rules.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update",
           "opt_state_specs", "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # float32 | bfloat16 | int8
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(math.pi * t))


# ----------------------------------------------------------------------
# int8 blockwise moment quantisation
# ----------------------------------------------------------------------

def _q8(x):
    """Symmetric per-channel int8 quantisation along the last axis."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def _encode(x, state_dtype: str):
    if state_dtype == "int8":
        q, s = _q8(x)
        return {"q": q, "s": s}
    return x.astype(jnp.bfloat16 if state_dtype == "bfloat16"
                    else jnp.float32)


def _decode(enc, state_dtype: str):
    if state_dtype == "int8":
        return _dq8(enc["q"], enc["s"])
    return enc.astype(jnp.float32)


def _is_moment(leaf):
    return isinstance(leaf, dict) and set(leaf) == {"q", "s"}


# ----------------------------------------------------------------------

def init_opt_state(params, cfg: AdamWConfig):
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _encode(z, cfg.state_dtype)

    moments = jax.tree.map(zero_like, params)
    return {
        "m": moments,
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs, state_dtype: str):
    """Logical specs for the opt state, parallel to ``init_opt_state``."""
    def spec_of(s):
        s = tuple(s)
        if state_dtype == "int8":
            return {"q": s, "s": s[:-1] + ("null",)}
        return s

    moment_specs = jax.tree.map(spec_of, param_specs,
                                is_leaf=lambda x: isinstance(x, tuple))
    return {"m": moment_specs, "v": moment_specs, "step": ("null",)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1, 2))
def adamw_update(grads, params, opt_state, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m_enc, v_enc):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _decode(m_enc, cfg.state_dtype) + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(v_enc, cfg.state_dtype) \
            + (1 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:                       # decoupled wd, matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, _encode(m, cfg.state_dtype), \
            _encode(v, cfg.state_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats
