"""Public jit'd wrapper for the Pallas back projection kernel.

Handles everything the kernel assumes away: zero-padding the projection to
the 1-pixel border the zero-outside semantics rely on, rounding the padded
buffer up so every (band, width) strip slice is in-bounds, validating the
static strip size against the host planner, and falling back to
``interpret=True`` off-TPU so the same entry point works everywhere
(kernels are *validated* on CPU, *targeted* at TPU).
"""

from __future__ import annotations

import functools
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backproject import (DEFAULT_PBATCH, GeomStatic,
                                    strip_wire_dtype)
from repro.core.clipping import (_round8, _round128, plan_strips,
                                 shared_window_requirement)
from repro.core.geometry import Geometry

from .backproject import (backproject_volume_pallas,
                          backproject_volume_pallas_batch)

__all__ = ["pallas_backproject_one", "pallas_backproject_batch",
           "validate_strip_config", "shared_window_dims", "clamp_tiles"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def clamp_tiles(gs: GeomStatic, ty: int, chunk: int, band: int,
                width: int) -> tuple[int, int, int, int]:
    """Geometry-clamp the kernel tile parameters.

    The single definition both :func:`pallas_backproject_one` and the
    autotuner's candidate validation go through, so a config validated
    by the sweep is exactly the config the kernel will run.
    """
    ty = min(ty, gs.L)
    chunk = min(chunk, gs.L)
    band = min(band, max(8, gs.n_v + 2 + (-(gs.n_v + 2)) % 8))
    width = min(width, max(128, gs.n_u + 2 + (-(gs.n_u + 2)) % 128))
    return ty, chunk, band, width


# Sublane tile per wire itemsize (f32 8, bf16 16, int8 32): padded row
# counts are rounded to this so every (band, width) slice is aligned.
_SUBLANE = {1: 32, 2: 16, 4: 8}


def _pad_up(image, band: int, width: int, dtype=None):
    """1-pixel zero border, then round rows/cols up to slice-safe sizes.

    Rows are rounded to a multiple of the sublane tile (8 for f32, 16
    for 2-byte wire dtypes, 32 for 1-byte) and cols to a multiple of
    128 (lane tile), and at least (band, width), so any clamped
    ``(band, width)`` dynamic slice stays in-bounds and
    hardware-aligned.  ``dtype`` casts the image to the strip wire
    dtype *before* padding (``None`` leaves the dtype — and the f32
    bits — untouched).
    """
    if dtype is not None:
        image = image.astype(dtype)
    sub = _SUBLANE.get(image.dtype.itemsize, 8)
    n_v, n_u = image.shape
    rows = max(band, n_v + 2)
    rows += (-rows) % sub
    cols = max(width, n_u + 2)
    cols += (-cols) % 128
    return jnp.pad(image, ((1, rows - n_v - 1), (1, cols - n_u - 1)))


def validate_strip_config(geom: Geometry, A: np.ndarray, *, ty: int,
                          chunk: int, band: int, width: int,
                          micro: bool = False, micro_group: int = 8,
                          micro_band: int = 8,
                          micro_width: int = 32) -> None:
    """Host-side check that (band, width) covers every tile footprint.

    A tile spans ``ty`` lines x ``chunk`` voxels; per-line strip needs are
    computed exactly by the planner (monotone-beam property), and adjacent
    lines' strips are merged by taking min/max origins.  Raises with the
    required sizes if the static config is too small — silent tap loss is
    never possible.

    With ``micro=True`` the per-group ``(micro_band, micro_width)``
    window is checked too: the micro kernel selects taps from a window
    sliced out of the strip, and a window smaller than a group's tap
    footprint drops taps exactly as silently as an undersized strip
    (``micro_band`` defaulted to 4 until this check existed).  The
    planner run with ``chunk=micro_group`` gives the exact per-group
    footprint.
    """
    plan = plan_strips(geom, A, chunk=chunk)
    r0 = plan.r0.astype(np.int64)
    c0 = plan.c0.astype(np.int64)
    # Merge ty adjacent lines: worst-case span = max over the group of
    # (origin + required extent) - min origin.
    L = geom.L
    g = r0.reshape(L, L // ty, ty, -1)
    span_r = g.max(axis=2) - g.min(axis=2) + plan.required_band
    gc = c0.reshape(L, L // ty, ty, -1)
    span_c = gc.max(axis=2) - gc.min(axis=2) + plan.required_width
    need_band, need_width = int(span_r.max()), int(span_c.max())
    if band < need_band or width < need_width:
        raise ValueError(
            f"strip config (band={band}, width={width}) does not cover the "
            f"tile footprint; need at least (band={need_band}, "
            f"width={need_width}) for ty={ty}, chunk={chunk}")
    if micro:
        if chunk % micro_group:
            raise ValueError(
                f"micro_group={micro_group} must divide chunk={chunk}")
        gplan = plan_strips(geom, A, chunk=micro_group)
        # A full-strip window can never lose a tap (its origin clamps
        # into the strip), so the requirement saturates at the strip
        # dimensions — mirrors validate_strip_opts' full-detector rule.
        need_gb = min(gplan.required_band, band)
        need_gw = min(gplan.required_width, width)
        if micro_band < need_gb or micro_width < need_gw:
            raise ValueError(
                f"micro window (micro_band={micro_band}, "
                f"micro_width={micro_width}) does not cover the "
                f"{micro_group}-voxel group tap footprint; need at least "
                f"(micro_band={need_gb}, micro_width={need_gw}) — "
                f"undersized micro windows drop taps silently")


def _encode_padded(image, band: int, width: int):
    """Pad (to the int8 sublane tile) then encode once for the int8
    wire.

    The f32 image is zero-bordered and rounded up to the 1-byte tile
    shape *first*, then row-encoded (:func:`repro.quant.quantize_rows`
    — per-row affine grid, residual feedback along the row), so pad
    rows/cols are all-zero rows that decode to exactly 0.0 and the
    codes slab is directly DMA-sliceable.  Returns ``(codes, scales)``
    with ``codes`` int8 ``(rows, cols)`` and ``scales`` f32 ``(2,
    rows)`` — ``[0] = scale``, ``[1] = offset`` — the layout
    :func:`repro.kernels.backproject._dequant_strip` reads.
    """
    from repro.quant import quantize_rows

    sub = _SUBLANE[1]
    n_v, n_u = image.shape
    rows = max(band, n_v + 2)
    rows += (-rows) % sub
    cols = max(width, n_u + 2)
    cols += (-cols) % 128
    padded = jnp.pad(image.astype(jnp.float32),
                     ((1, rows - n_v - 1), (1, cols - n_u - 1)))
    rq = quantize_rows(padded)
    return rq.codes, jnp.stack([rq.scale, rq.offset])


def _encode_padded_stack(images, band: int, width: int):
    """Stacked :func:`_encode_padded`: ``(P, rows, cols)`` int8 codes
    plus ``(P, 2, rows)`` per-projection scale blocks."""
    from repro.quant import quantize_rows

    sub = _SUBLANE[1]
    n_proj, n_v, n_u = images.shape
    rows = max(band, n_v + 2)
    rows += (-rows) % sub
    cols = max(width, n_u + 2)
    cols += (-cols) % 128
    padded = jnp.pad(images.astype(jnp.float32),
                     ((0, 0), (1, rows - n_v - 1), (1, cols - n_u - 1)))
    rq = jax.vmap(quantize_rows)(padded)
    return rq.codes, jnp.stack([rq.scale, rq.offset], axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("gs", "ty", "chunk", "band", "width",
                     "double_buffer", "db_depth", "micro", "micro_group",
                     "micro_band", "micro_width", "strip_dtype",
                     "interpret"))
def _run(volume, image, A, gs: GeomStatic, ty, chunk, band, width,
         double_buffer, db_depth, micro, micro_group, micro_band,
         micro_width, strip_dtype, interpret):
    wire = strip_wire_dtype(strip_dtype)
    if wire is jnp.int8:
        padded, scales = _encode_padded(image, band, width)
    else:
        padded = _pad_up(image, band, width, wire)
        scales = None
    return backproject_volume_pallas(
        volume, padded, A,
        o_mm=(gs.O, gs.MM), n_u=gs.n_u, n_v=gs.n_v,
        ty=ty, chunk=chunk, band=band, width=width,
        double_buffer=double_buffer, db_depth=db_depth, micro=micro,
        micro_group=micro_group, micro_band=micro_band,
        micro_width=micro_width, scales=scales, interpret=interpret)


def pallas_backproject_one(volume, image, A, geom: Geometry | GeomStatic,
                           *, ty: int = 8, chunk: int = 128, band: int = 16,
                           width: int = 512, double_buffer: bool = False,
                           db_depth: int = 2, micro: bool = False,
                           micro_group: int = 8, micro_band: int = 8,
                           micro_width: int = 32,
                           strip_dtype: str = "float32",
                           interpret: bool | None = None,
                           validate: bool = False,
                           strategy: str = "fixed"):
    """Add one projection to ``volume`` using the Pallas kernel.

    ``strip_dtype="bfloat16"`` carries the padded projection (and so
    every strip DMA and the VMEM scratch) in bf16; the kernels already
    upcast the window to f32 at the one-hot matmul and accumulate in
    f32, so only the tap values are rounded.  ``strip_dtype="int8"``
    encodes the padded projection once (:func:`_encode_padded` — per-row
    affine codes + error feedback) and moves 1-byte codes on every strip
    DMA, dequantising in-register next to the accumulator.  The f32
    default path is bitwise-unchanged.

    ``interpret=None`` auto-selects: compiled on TPU, interpreter
    elsewhere.  ``validate=True`` runs the host planner check first
    (cheap; recommended once per geometry) — with ``micro=True`` it also
    checks the ``(micro_band, micro_width)`` group window, the hazard
    that made ``micro_band=4`` silently drop taps.  ``double_buffer=True``
    overlaps strip DMA with compute (hillclimb CT-3), ``db_depth`` slots
    in rotation.

    ``strategy="auto"`` pulls the tile parameters (``ty``/``chunk``/
    ``band``/``width``/``double_buffer``/``db_depth``/``micro``) from
    the process dispatcher (:mod:`repro.dispatch` — cache hit, in-situ
    first-call selection, or a logged fallback) for this geometry/
    backend/device; when no decision carries a kernel config the
    explicitly passed parameters stand.  (``pbatch`` is the one tuned
    key with no single-projection meaning — there is nothing to batch
    here; batch callers resolve it through
    :func:`pallas_backproject_batch`.)
    """
    gs = geom if isinstance(geom, GeomStatic) else GeomStatic.of(geom)
    if strategy == "auto":
        from repro.dispatch import get_dispatcher

        tuned = get_dispatcher().resolve_kernel(geom)
        if tuned is not None:
            ty = int(tuned.get("ty", ty))
            chunk = int(tuned.get("chunk", chunk))
            band = int(tuned.get("band", band))
            width = int(tuned.get("width", width))
            double_buffer = bool(tuned.get("double_buffer", double_buffer))
            # A tuned pipeline decision was timed at a specific depth;
            # resolve it with the flag (same rotation ledger on the
            # single-projection kernel as on the batched one).
            db_depth = int(tuned.get("db_depth", db_depth))
            micro = bool(tuned.get("micro", micro))
            # The tuned micro decision was validated at a specific
            # window; resolve the whole window, not just the flag.
            micro_group = int(tuned.get("micro_group", micro_group))
            micro_band = int(tuned.get("micro_band", micro_band))
            micro_width = int(tuned.get("micro_width", micro_width))
            strip_dtype = str(tuned.get("strip_dtype", strip_dtype))
    elif strategy != "fixed":
        raise ValueError(
            f"unknown strategy {strategy!r}; want 'fixed' or 'auto'")
    strip_wire_dtype(strip_dtype)   # loud on typos, before any tracing
    ty, chunk, band, width = clamp_tiles(gs, ty, chunk, band, width)
    micro_band = min(micro_band, band)
    micro_width = min(micro_width, width)
    if validate:
        if isinstance(geom, GeomStatic):
            raise ValueError("validate=True needs the full Geometry")
        validate_strip_config(geom, np.asarray(A, np.float64), ty=ty,
                              chunk=chunk, band=band, width=width,
                              micro=micro, micro_group=micro_group,
                              micro_band=micro_band,
                              micro_width=micro_width)
    if interpret is None:
        interpret = not _on_tpu()
    return _run(jnp.asarray(volume), jnp.asarray(image),
                jnp.asarray(A, jnp.float32), gs, ty, chunk, band, width,
                double_buffer, int(db_depth), micro, micro_group,
                micro_band, micro_width, strip_dtype, interpret)


def _pad_up_stack(images, band: int, width: int, dtype=None):
    """The stacked analogue of :func:`_pad_up`: pad the whole projection
    stack once (1-pixel zero border + slice-safe round-up; ``dtype``
    casts to the strip wire dtype first, ``None`` = untouched f32)."""
    if dtype is not None:
        images = images.astype(dtype)
    sub = _SUBLANE.get(images.dtype.itemsize, 8)
    n_proj, n_v, n_u = images.shape
    rows = max(band, n_v + 2)
    rows += (-rows) % sub
    cols = max(width, n_u + 2)
    cols += (-cols) % 128
    return jnp.pad(images, ((0, 0), (1, rows - n_v - 1),
                            (1, cols - n_u - 1)))


@functools.partial(
    jax.jit,
    static_argnames=("gs", "ty", "chunk", "band", "width", "pbatch",
                     "double_buffer", "db_depth", "micro", "micro_group",
                     "micro_band", "micro_width", "shared_window",
                     "strip_dtype", "interpret"))
def _run_batched(volume, images, mats, gs: GeomStatic, ty, chunk, band,
                 width, pbatch, double_buffer, db_depth, micro,
                 micro_group, micro_band, micro_width, shared_window,
                 strip_dtype, interpret):
    from repro.core.backproject import _stream_batches

    # With shared_window the (band, width) passed here are already the
    # superset-window dims sized by the caller.
    wire = strip_wire_dtype(strip_dtype)
    if wire is jnp.int8:
        # Encode once for the whole stack; _stream_batches slices the
        # (codes, scales) pair per batch as one pytree.
        padded = _encode_padded_stack(images, band, width)
    else:
        padded = _pad_up_stack(images, band, width, wire)

    def call(vol, imgs, A):
        codes, scl = imgs if isinstance(imgs, tuple) else (imgs, None)
        return backproject_volume_pallas_batch(
            vol, codes, A, o_mm=(gs.O, gs.MM), n_u=gs.n_u, n_v=gs.n_v,
            ty=ty, chunk=chunk, band=band, width=width,
            double_buffer=double_buffer, db_depth=db_depth, micro=micro,
            micro_group=micro_group, micro_band=micro_band,
            micro_width=micro_width, shared_window=shared_window,
            scales=scl, interpret=interpret)

    return _stream_batches(padded, mats, volume, pbatch, call)


# Projection stacks already proven covered by (geom, tile config) — the
# planner pass is host-side numpy and paid once per distinct problem,
# mirroring repro.core.backproject._VALIDATED_STRIPS.
_VALIDATED_STACKS: set = set()

# (gs, ty, chunk, pbatch, sha1(mats)) -> planner-tight superset needs.
# The group planner pass is host-side numpy over every projection; pay
# it once per distinct problem like the validation memos above.
_SHARED_REQS: dict = {}


def shared_window_dims(geom: Geometry, mats, *, ty: int, chunk: int,
                       pbatch: int, shared_band: int | None = None,
                       shared_width: int | None = None
                       ) -> tuple[int, int]:
    """Size (and check) the shared superset window for a projection set.

    Returns the ``(band, width)`` the shared-window batch kernel must
    run with: the planner-tight group requirement
    (:func:`repro.core.clipping.shared_window_requirement`, saturated at
    the full padded detector — a full-detector window can never lose a
    tap), rounded up to hardware tiles when auto-sized.  Explicit dims
    smaller than the requirement raise — an undersized superset window
    drops taps silently, same hazard class as an undersized strip.
    """
    gs = GeomStatic.of(geom)
    mats64 = np.asarray(mats, np.float64).reshape(-1, 3, 4)
    key = (gs, ty, chunk, pbatch,
           hashlib.sha1(mats64.tobytes()).hexdigest())
    need = _SHARED_REQS.get(key)
    if need is None:
        need = shared_window_requirement(geom, mats64, ty=ty, chunk=chunk,
                                         pbatch=pbatch)
        if len(_SHARED_REQS) >= 4096:
            _SHARED_REQS.clear()
        _SHARED_REQS[key] = need
    need_band = min(need[0], gs.n_v + 2)
    need_width = min(need[1], gs.n_u + 2)
    band = _round8(need_band) if shared_band is None else int(shared_band)
    width = (_round128(need_width) if shared_width is None
             else int(shared_width))
    if band < need_band or width < need_width:
        raise ValueError(
            f"shared window (shared_band={band}, shared_width={width}) "
            f"does not cover the projection group's superset footprint; "
            f"need at least (shared_band={need_band}, "
            f"shared_width={need_width}) for ty={ty}, chunk={chunk}, "
            f"pbatch={pbatch} — undersized windows drop taps silently")
    return band, width


def pallas_backproject_batch(volume, images, mats,
                             geom: Geometry | GeomStatic, *, ty: int = 8,
                             chunk: int = 128, band: int = 16,
                             width: int = 512,
                             pbatch: int = DEFAULT_PBATCH,
                             double_buffer: bool = False,
                             db_depth: int = 2, micro: bool = False,
                             micro_group: int = 8, micro_band: int = 8,
                             micro_width: int = 32,
                             shared_window: bool = False,
                             shared_band: int | None = None,
                             shared_width: int | None = None,
                             strip_dtype: str = "float32",
                             interpret: bool | None = None,
                             validate: bool = True,
                             strategy: str = "fixed"):
    """Add a stack of projections to ``volume``, ``pbatch`` per kernel
    launch, with the volume tile resident in VMEM across the in-kernel
    projection loop (DESIGN.md §7).

    ``images``: unpadded ``(n_proj, n_v, n_u)`` filtered projections —
    padded once for the whole stack; ``mats``: ``(n_proj, 3, 4)``.
    ``n_proj`` is chunked into ``pbatch``-sized batches inside one jit
    (a ``pbatch ∤ n_proj`` remainder runs as one final smaller batch).
    Every projection's footprint is validated against the host planner
    by default (memoised per problem) — with ``micro=True`` the
    ``(micro_band, micro_width)`` group window included; pass
    ``validate=False`` only when the exact (geometry, matrices, tile)
    triple was already validated.

    ``double_buffer=True`` selects the deep DMA pipeline
    (``db_depth``-slot rotation crossing the plane loop, DESIGN.md §9);
    ``micro=True`` the per-group micro-window compute.  ``strategy=
    "auto"`` pulls the full tuned surface — ``ty``/``chunk``/``band``/
    ``width``, ``pbatch``, *and* the ``double_buffer``/``db_depth``/
    ``micro``/``micro_*`` variant flags — from the process dispatcher
    (:mod:`repro.dispatch`) for this key: every tuned decision runs the
    kernel it was timed on, and an impossible combination raises
    instead of being shed.

    ``strip_dtype="bfloat16"`` carries the padded stack (all strip/
    window DMAs and the VMEM scratch) in bf16 — the kernels upcast to
    f32 at the one-hot matmul and accumulate in f32, so only the tap
    values round; ``strip_dtype="int8"`` encodes the stack once into
    per-row affine codes plus a ``(pbatch, 2, rows)`` scale block
    (:func:`_encode_padded_stack`) and every strip/window DMA moves
    1-byte codes, dequantised in-register; the f32 default is
    bitwise-unchanged.
    ``shared_window=True`` selects the superset-window kernel: one
    ``(pbatch, band, width)`` window DMA per (volume tile, projection
    group) instead of ``pbatch`` strip fetches.  The window dims are
    sized by the host group planner (:func:`shared_window_dims`) — pass
    ``shared_band``/``shared_width`` to pin them, which raises if they
    under-cover.  Sizing needs the full :class:`Geometry` (not a bare
    ``GeomStatic``) and runs regardless of ``validate`` — it is the
    correctness guard for this variant, not an optional check.
    """
    gs = geom if isinstance(geom, GeomStatic) else GeomStatic.of(geom)
    if strategy == "auto":
        from repro.dispatch import get_dispatcher

        tuned = get_dispatcher().resolve_kernel(geom)
        if tuned is not None:
            ty = int(tuned.get("ty", ty))
            chunk = int(tuned.get("chunk", chunk))
            band = int(tuned.get("band", band))
            width = int(tuned.get("width", width))
            pbatch = int(tuned.get("pbatch", pbatch))
            double_buffer = bool(tuned.get("double_buffer", double_buffer))
            db_depth = int(tuned.get("db_depth", db_depth))
            micro = bool(tuned.get("micro", micro))
            # A tuned micro decision was validated at a specific window;
            # resolve the whole window, not just the flag.
            micro_group = int(tuned.get("micro_group", micro_group))
            micro_band = int(tuned.get("micro_band", micro_band))
            micro_width = int(tuned.get("micro_width", micro_width))
            shared_window = bool(tuned.get("shared_window", shared_window))
            shared_band = tuned.get("shared_band", shared_band)
            shared_width = tuned.get("shared_width", shared_width)
            strip_dtype = str(tuned.get("strip_dtype", strip_dtype))
    elif strategy != "fixed":
        raise ValueError(
            f"unknown strategy {strategy!r}; want 'fixed' or 'auto'")
    if (micro and double_buffer
            or shared_window and (micro or double_buffer)):
        raise ValueError(
            f"batch kernel variants are exclusive: got micro={micro}, "
            f"double_buffer={double_buffer}, shared_window="
            f"{shared_window}; a tuned decision names exactly one")
    if double_buffer and int(db_depth) < 2:
        raise ValueError(
            f"db_depth={db_depth}: the pipelined batch kernel needs an "
            f"in-flight slot rotation of at least 2")
    strip_wire_dtype(strip_dtype)   # loud on typos, before any tracing
    ty, chunk, band, width = clamp_tiles(gs, ty, chunk, band, width)
    micro_band = min(micro_band, band)
    micro_width = min(micro_width, width)
    images = jnp.asarray(images)
    mats_f32 = jnp.asarray(mats, jnp.float32)
    n_proj = int(images.shape[0])
    pbatch = max(1, min(int(pbatch), n_proj)) if n_proj else 1
    if shared_window:
        # Mandatory sizing/coverage pass — see the docstring.  The
        # resulting superset dims *replace* (band, width) for the rest
        # of the pipeline: they are what the kernel DMAs and what the
        # one-hot selectors span.
        if isinstance(geom, GeomStatic):
            raise ValueError(
                "shared_window=True needs the full Geometry: the host "
                "group planner sizes the superset window")
        band, width = shared_window_dims(
            geom, mats, ty=ty, chunk=chunk, pbatch=pbatch,
            shared_band=shared_band, shared_width=shared_width)
        _, _, band, width = clamp_tiles(gs, ty, chunk, band, width)
    elif validate:
        if isinstance(geom, GeomStatic):
            raise ValueError("validate=True needs the full Geometry")
        mats64 = np.asarray(mats, np.float64).reshape(-1, 3, 4)
        key = (gs, ty, chunk, band, width, micro,
               (micro_group, micro_band, micro_width) if micro else None,
               hashlib.sha1(mats64.tobytes()).hexdigest())
        if key not in _VALIDATED_STACKS:
            for A in mats64:
                validate_strip_config(geom, A, ty=ty, chunk=chunk,
                                      band=band, width=width, micro=micro,
                                      micro_group=micro_group,
                                      micro_band=micro_band,
                                      micro_width=micro_width)
            if len(_VALIDATED_STACKS) >= 4096:
                _VALIDATED_STACKS.clear()
            _VALIDATED_STACKS.add(key)
    if interpret is None:
        interpret = not _on_tpu()
    return _run_batched(jnp.asarray(volume), images, mats_f32, gs, ty,
                        chunk, band, width, pbatch, double_buffer,
                        int(db_depth), micro, micro_group, micro_band,
                        micro_width, shared_window, strip_dtype,
                        interpret)
