"""Public jit'd wrapper for the Pallas back projection kernel.

Handles everything the kernel assumes away: zero-padding the projection to
the 1-pixel border the zero-outside semantics rely on, rounding the padded
buffer up so every (band, width) strip slice is in-bounds, validating the
static strip size against the host planner, and falling back to
``interpret=True`` off-TPU so the same entry point works everywhere
(kernels are *validated* on CPU, *targeted* at TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backproject import GeomStatic
from repro.core.clipping import plan_strips
from repro.core.geometry import Geometry

from .backproject import backproject_volume_pallas

__all__ = ["pallas_backproject_one", "validate_strip_config",
           "clamp_tiles"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def clamp_tiles(gs: GeomStatic, ty: int, chunk: int, band: int,
                width: int) -> tuple[int, int, int, int]:
    """Geometry-clamp the kernel tile parameters.

    The single definition both :func:`pallas_backproject_one` and the
    autotuner's candidate validation go through, so a config validated
    by the sweep is exactly the config the kernel will run.
    """
    ty = min(ty, gs.L)
    chunk = min(chunk, gs.L)
    band = min(band, max(8, gs.n_v + 2 + (-(gs.n_v + 2)) % 8))
    width = min(width, max(128, gs.n_u + 2 + (-(gs.n_u + 2)) % 128))
    return ty, chunk, band, width


def _pad_up(image, band: int, width: int):
    """1-pixel zero border, then round rows/cols up to slice-safe sizes.

    Rows are rounded to a multiple of 8 (sublane tile) and cols to a
    multiple of 128 (lane tile), and at least (band, width), so any
    clamped ``(band, width)`` dynamic slice stays in-bounds and
    hardware-aligned.
    """
    n_v, n_u = image.shape
    rows = max(band, n_v + 2)
    rows += (-rows) % 8
    cols = max(width, n_u + 2)
    cols += (-cols) % 128
    return jnp.pad(image, ((1, rows - n_v - 1), (1, cols - n_u - 1)))


def validate_strip_config(geom: Geometry, A: np.ndarray, *, ty: int,
                          chunk: int, band: int, width: int) -> None:
    """Host-side check that (band, width) covers every tile footprint.

    A tile spans ``ty`` lines x ``chunk`` voxels; per-line strip needs are
    computed exactly by the planner (monotone-beam property), and adjacent
    lines' strips are merged by taking min/max origins.  Raises with the
    required sizes if the static config is too small — silent tap loss is
    never possible.
    """
    plan = plan_strips(geom, A, chunk=chunk)
    r0 = plan.r0.astype(np.int64)
    c0 = plan.c0.astype(np.int64)
    # Merge ty adjacent lines: worst-case span = max over the group of
    # (origin + required extent) - min origin.
    L = geom.L
    g = r0.reshape(L, L // ty, ty, -1)
    span_r = g.max(axis=2) - g.min(axis=2) + plan.required_band
    gc = c0.reshape(L, L // ty, ty, -1)
    span_c = gc.max(axis=2) - gc.min(axis=2) + plan.required_width
    need_band, need_width = int(span_r.max()), int(span_c.max())
    if band < need_band or width < need_width:
        raise ValueError(
            f"strip config (band={band}, width={width}) does not cover the "
            f"tile footprint; need at least (band={need_band}, "
            f"width={need_width}) for ty={ty}, chunk={chunk}")


@functools.partial(
    jax.jit,
    static_argnames=("gs", "ty", "chunk", "band", "width",
                     "double_buffer", "micro", "interpret"))
def _run(volume, image, A, gs: GeomStatic, ty, chunk, band, width,
         double_buffer, micro, interpret):
    padded = _pad_up(image, band, width)
    return backproject_volume_pallas(
        volume, padded, A,
        o_mm=(gs.O, gs.MM), n_u=gs.n_u, n_v=gs.n_v,
        ty=ty, chunk=chunk, band=band, width=width,
        double_buffer=double_buffer, micro=micro, interpret=interpret)


def pallas_backproject_one(volume, image, A, geom: Geometry | GeomStatic,
                           *, ty: int = 8, chunk: int = 128, band: int = 16,
                           width: int = 512, double_buffer: bool = False,
                           micro: bool = False,
                           interpret: bool | None = None,
                           validate: bool = False,
                           strategy: str = "fixed"):
    """Add one projection to ``volume`` using the Pallas kernel.

    ``interpret=None`` auto-selects: compiled on TPU, interpreter
    elsewhere.  ``validate=True`` runs the host planner check first
    (cheap; recommended once per geometry).  ``double_buffer=True``
    overlaps strip DMA with compute (hillclimb CT-3).

    ``strategy="auto"`` pulls the tile parameters (``ty``/``chunk``/
    ``band``/``width``/``double_buffer``/``micro``) from the autotuner
    cache (:mod:`repro.tune`) for this geometry/backend/device; when the
    key was never tuned the explicitly passed parameters stand.
    """
    gs = geom if isinstance(geom, GeomStatic) else GeomStatic.of(geom)
    if strategy == "auto":
        from repro.tune.cache import resolve_pallas_config

        tuned = resolve_pallas_config(gs)
        if tuned is not None:
            ty = int(tuned.get("ty", ty))
            chunk = int(tuned.get("chunk", chunk))
            band = int(tuned.get("band", band))
            width = int(tuned.get("width", width))
            double_buffer = bool(tuned.get("double_buffer", double_buffer))
            micro = bool(tuned.get("micro", micro))
    elif strategy != "fixed":
        raise ValueError(
            f"unknown strategy {strategy!r}; want 'fixed' or 'auto'")
    ty, chunk, band, width = clamp_tiles(gs, ty, chunk, band, width)
    if validate:
        if isinstance(geom, GeomStatic):
            raise ValueError("validate=True needs the full Geometry")
        validate_strip_config(geom, np.asarray(A, np.float64), ty=ty,
                              chunk=chunk, band=band, width=width)
    if interpret is None:
        interpret = not _on_tpu()
    return _run(jnp.asarray(volume), jnp.asarray(image),
                jnp.asarray(A, jnp.float32), gs, ty, chunk, band, width,
                double_buffer, micro, interpret)
