"""Jit'd wrapper: drop-in fused-sLSTM forward matching ssm.slstm_forward."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import dense

from .slstm import slstm_pallas

__all__ = ["fused_slstm_forward"]


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if not pad:
        return x
    cfgpad = [(0, 0)] * x.ndim
    cfgpad[axis] = (0, pad)
    return jnp.pad(x, cfgpad)


@functools.partial(jax.jit, static_argnames=("tb", "td", "seq_chunk",
                                             "interpret"))
def _run(zifo, r, tb, td, seq_chunk, interpret):
    return slstm_pallas(zifo, r, tb=tb, td=td, seq_chunk=seq_chunk,
                        interpret=interpret)


def fused_slstm_forward(params, cfg, x, *, dtype=jnp.bfloat16,
                        interpret: bool | None = None):
    """Numerically matches :func:`repro.models.ssm.slstm_forward`.

    The gate projection and out-projection run as normal XLA matmuls;
    only the recurrence runs in the fused kernel (HBM traffic: one
    read of the gates, one write of the hidden states).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, _ = x.shape
    di = cfg.d_inner
    zifo = dense(params, "zifo", x, dtype).astype(jnp.float32)
    zifo = zifo.reshape(B, S, 4, di)
    r = params["r_zifo"].astype(jnp.float32)

    tb = min(8, B)
    td = min(128, di)
    seq_chunk = min(256, S)
    zp = _pad_to(_pad_to(_pad_to(zifo, tb, 0), seq_chunk, 1), td, 3)
    rp = _pad_to(r, td, 1)
    hs = _run(zp, rp, tb, td, seq_chunk, interpret)
    hs = hs[:B, :S, :di].astype(dtype)
    return dense(params, "out_proj", hs, dtype)
