"""Pallas TPU kernel: one-hot-matmul gather ("the MXU as texture unit").

The embedding/MoE-side instantiation of the paper's technique: instead of
asking the backend for a hardware gather (``table[ids]`` -> XLA gather HLO,
which XLA:TPU lowers to a serialised descriptor loop — the exact analogue
of KNC's microcoded ``vgatherdps``), the rows are *computed*:

    out[n, :] = onehot(ids[n]) @ table

The vocabulary axis is tiled by the grid, so each step does a
``(TN, C) @ (C, D)`` MXU matmul and accumulates into the output block;
the one-hot is built on the VPU with an iota compare.  No gather HLO
exists anywhere in the lowering (verified by
``benchmarks/table2_op_census.py``).

Grid: ``(N / TN, V / C)``; the output block for row-tile ``i`` is revisited
across all vocab chunks ``j`` (initialised at ``j == 0``) — the standard
Pallas reduction-grid pattern.  The table block ``(C, D)`` streams through
VMEM once per row-tile; arithmetic intensity is ``2 * TN`` flops per table
byte, so for ``TN >= ~200`` the kernel turns a memory-bound serialised
gather into a compute-dense MXU stream (Table 4 analogue measures the
crossover).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["onehot_gather_kernel", "onehot_gather_pallas"]


def onehot_gather_kernel(ids_ref, table_ref, out_ref, *, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    base = j * chunk
    ids = ids_ref[...]                                   # (TN, 1) int32
    iota = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], chunk), 1)
    oh = (iota == (ids - base)).astype(table_ref.dtype)  # (TN, C)
    out_ref[...] += jax.lax.dot_general(
        oh, table_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)


def onehot_gather_pallas(table: jax.Array, ids: jax.Array, *,
                         row_tile: int = 256, chunk: int = 512,
                         interpret: bool = False) -> jax.Array:
    """Gather ``table[ids]`` with zero gather HLOs.

    ``table``: (V, D); ``ids``: (N,) int32.  V must divide by ``chunk``
    and N by ``row_tile`` (ops.py pads both).  Out-of-range ids return
    zero rows (one-hot matches nothing) — the same zero-padding semantics
    the back projection uses.
    """
    V, D = table.shape
    N = ids.shape[0]
    assert V % chunk == 0 and N % row_tile == 0, (V, chunk, N, row_tile)
    grid = (N // row_tile, V // chunk)

    kernel = functools.partial(onehot_gather_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((chunk, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), table.dtype),
        interpret=interpret,
        name="onehot_gather",
    )(ids.reshape(N, 1).astype(jnp.int32), table)
