"""Pallas TPU kernel: strip-blocked cone-beam back projection.

The TPU-native re-think of the paper's fastest CPU scheme (AVX/FMA3
"pairwise loads beat hardware gather", section 6.1), built from three
mechanisms the x86 kernels could only approximate:

1. **Strip DMA instead of gather** — per grid step the kernel computes the
   detector footprint of its ``(TY, CHUNK)`` voxel tile *in-kernel* (Part 1
   on the VPU), then issues one ``make_async_copy`` HBM->VMEM block copy of
   the minimal ``(band, width)`` strip.  One DMA descriptor replaces
   ``4 * TY * CHUNK`` scattered loads: this is the pairwise-load idea at
   DMA granularity.
2. **MXU as texture unit** — the vertical interpolation is a banded
   one-hot matmul ``rowsel(P, band) @ strip(band, width)`` on the MXU; the
   horizontal 2-tap selection runs as iota-compare/select on the VPU.
   Out-of-band one-hot rows are identically zero, which (with the 1-pixel
   zero border added by ops.py) gives exact zero-outside-detector
   semantics with *no* per-tap conditionals — the paper's zero-padded
   buffer trick (section 5.1.1).
3. **Grid pipelining instead of SMT** — KNC needed 4-way SMT to hide
   gather latency and still failed (section 6.4); here the volume-tile
   loads/stores are pipelined by the Pallas grid machinery, and the strip
   DMA for step ``k+1`` can be issued during step ``k``'s compute
   (double-buffered variant, ``double_buffer=True`` — hillclimb CT-2 in
   EXPERIMENTS.md).

Semantics are identical to ``repro.core.backproject.sample_scalar`` +
``accumulate`` (floor bilinear, zero outside, ``1/w^2`` weighting), which
is the oracle in ``backproject_ref.py``; correctness requires
``band``/``width`` to cover each tile's footprint (guaranteed by the
host-side planner in ``repro.core.clipping`` — ops.py checks it).

VMEM budget per step (defaults TY=8, CHUNK=128, band=16, width=512, f32):
strip 32 KB (x2 when double-buffered) + rowmix 2 MB + volume tile 4 KB —
comfortably inside 16 MB, leaving the pipeline room to prefetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["backproject_kernel", "backproject_kernel_batch",
           "backproject_kernel_batch_db", "backproject_kernel_batch_micro",
           "backproject_kernel_batch_shared",
           "backproject_volume_pallas", "backproject_volume_pallas_batch"]

_EPS_W = 1e-6


def _read_A(A_ref, p=None):
    """Load a 3x4 projection matrix from SMEM as a nested scalar tuple.

    ``p`` indexes a stacked ``(P, 3, 4)`` matrix buffer (batch kernel;
    ``p`` may be a traced loop index — SMEM scalar loads take dynamic
    indices).  Scalars instead of a reloaded array so every kernel
    variant shares one Part-1 implementation.
    """
    if p is None:
        return tuple(tuple(A_ref[i, j] for j in range(4)) for i in range(3))
    return tuple(tuple(A_ref[p, i, j] for j in range(4)) for i in range(3))


def _part1_tile(A, o_mm, z, y0, x0, ty, chunk):
    """Part 1 on the VPU: ICS coords for a (ty, chunk) voxel tile.

    ``A`` is the nested scalar tuple from :func:`_read_A`.
    """
    O, MM = o_mm
    ys = (y0 + jax.lax.broadcasted_iota(jnp.float32, (ty, chunk), 0))
    xs = (x0 + jax.lax.broadcasted_iota(jnp.float32, (ty, chunk), 1))
    wx = O + xs * MM
    wy = O + ys * MM
    wz = O + z.astype(jnp.float32) * MM
    u = wx * A[0][0] + wy * A[0][1] + wz * A[0][2] + A[0][3]
    v = wx * A[1][0] + wy * A[1][1] + wz * A[1][2] + A[1][3]
    w = wx * A[2][0] + wy * A[2][1] + wz * A[2][2] + A[2][3]
    r = jnp.where(w > _EPS_W, 1.0 / w, 0.0)   # reciprocal trick (paper 5.1)
    return u * r, v * r, w, r


def _strip_origin(A, o_mm, z, y0, x0, *, n_u, n_v, ty, chunk, band, width,
                  pad_rows, pad_cols):
    """Strip origin for a (ty, chunk) tile from its four *corner* voxels.

    The cheap origin-only geometry: ``w`` is affine over the tile, so its
    minimum sits at a corner, and where ``w > 0`` both detector
    coordinates are monotone along each voxel axis — the tile extremes of
    ``ix``/``iy`` are corner values.  Twelve scalar FMAs per corner
    replace the full ``(ty, chunk)`` Part-1 pass the double-buffered
    kernel used to run just to obtain a prefetch address.  Matches the
    full-tile ``min`` exactly whenever ``w > eps`` across the tile (every
    sane cone-beam geometry); prefetch and compute always agree because
    both sides call this one helper.
    """
    O, MM = o_mm
    wz = O + z.astype(jnp.float32) * MM
    r_lo = c_lo = None
    for dy in (0.0, float(ty - 1)):
        for dx in (0.0, float(chunk - 1)):
            wy = O + (y0 + dy) * MM
            wx = O + (x0 + dx) * MM
            u = wx * A[0][0] + wy * A[0][1] + wz * A[0][2] + A[0][3]
            v = wx * A[1][0] + wy * A[1][1] + wz * A[1][2] + A[1][3]
            w = wx * A[2][0] + wy * A[2][1] + wz * A[2][2] + A[2][3]
            r = jnp.where(w > _EPS_W, 1.0 / w, 0.0)
            ix = jnp.clip(u * r, -1.0, jnp.float32(n_u))
            iy = jnp.clip(v * r, -1.0, jnp.float32(n_v))
            c_lo = ix if c_lo is None else jnp.minimum(c_lo, ix)
            r_lo = iy if r_lo is None else jnp.minimum(r_lo, iy)
    r0 = jnp.clip(jnp.floor(r_lo).astype(jnp.int32), 0, pad_rows - band)
    c0 = jnp.clip(jnp.floor(c_lo).astype(jnp.int32), 0, pad_cols - width)
    return r0, c0


def _tile_geometry(A, o_mm, z, y0, x0, *, n_u, n_v, ty, chunk, band,
                   width, pad_rows, pad_cols):
    """Part 1 + strip origin + activity flag for one (ty, chunk) tile."""
    ix, iy, w, r = _part1_tile(A, o_mm, z, y0, x0, ty, chunk)
    ix_c = jnp.clip(ix, -1.0, jnp.float32(n_u))
    iy_c = jnp.clip(iy, -1.0, jnp.float32(n_v))
    r0 = jnp.clip(jnp.floor(jnp.min(iy_c)).astype(jnp.int32),
                  0, pad_rows - band)
    c0 = jnp.clip(jnp.floor(jnp.min(ix_c)).astype(jnp.int32),
                  0, pad_cols - width)
    active = _tile_active(ix, iy, w, n_u, n_v)
    return ix, iy, w, r, r0, c0, active


def _tile_active(ix, iy, w, n_u, n_v):
    """Does any voxel of the tile project onto the detector?"""
    return ((jnp.min(ix) < jnp.float32(n_u)) & (jnp.max(ix) > -1.0)
            & (jnp.min(iy) < jnp.float32(n_v)) & (jnp.max(iy) > -1.0)
            & (jnp.max(w) > _EPS_W))


def _dequant_strip(strip, scl_ref, r0, band, p=None):
    """Decode an int8 code strip in-register, next to the accumulator.

    ``scl_ref`` is the per-detector-row scale block, VMEM-resident for
    the whole kernel: ``scl_ref[0] = scale``, ``scl_ref[1] = offset``
    per padded row (stacked ``(P, 2, rows)`` in the batch kernels,
    indexed by ``p``), so ``value = code * scale[row] + offset[row]``.
    ``scl_ref=None`` means the wire is not quantised and the strip
    passes through untouched — every variant calls this unconditionally
    and the f32 path traces to a no-op.  Dequantisation happens *here*,
    after the DMA: only 1-byte codes ever move on the strip wire, and
    only the resident ``(band, width)`` window widens to f32.
    """
    if scl_ref is None:
        return strip
    if p is None:
        scl = scl_ref[0, pl.ds(r0, band)]
        off = scl_ref[1, pl.ds(r0, band)]
    else:
        scl = scl_ref[p, 0, pl.ds(r0, band)]
        off = scl_ref[p, 1, pl.ds(r0, band)]
    return strip.astype(jnp.float32) * scl[:, None] + off[:, None]


def _tile_contrib(get_strip, ix, iy, r, r0, c0, *, ty, chunk, band, width):
    """Parts 2+3 for one tile against a resident (band, width) strip.

    Banded one-hot vertical interpolation on the MXU, 2-tap horizontal
    blend on the VPU, ``1/w²`` weighting folded in.  Taps outside the
    strip select all-zero one-hot rows and vanish — with the zero border
    this is the exact zero-outside-detector semantics.  Returns the f32
    ``(ty, chunk)`` contribution.

    ``get_strip`` is a zero-arg callable (wait on the strip DMA, read the
    scratch) invoked only once the one-hot selectors are built, so the
    copy overlaps the selector arithmetic.
    """
    fx = jnp.floor(ix)
    fy = jnp.floor(iy)
    sx = ix - fx
    sy = iy - fy
    # Padded-relative tap coordinates (+1: pad offset).
    rel_r = fy.astype(jnp.int32) + 1 - r0
    rel_c = fx.astype(jnp.int32) + 1 - c0

    p = ty * chunk
    rel_r_f = rel_r.reshape(p, 1)
    rel_c_f = rel_c.reshape(p, 1)
    sy_f = sy.reshape(p, 1)
    sx_f = sx.reshape(p, 1)

    biota = jax.lax.broadcasted_iota(jnp.int32, (p, band), 1)
    wiota = jax.lax.broadcasted_iota(jnp.int32, (p, width), 1)
    rowsel = ((biota == rel_r_f).astype(jnp.float32) * (1.0 - sy_f)
              + (biota == rel_r_f + 1).astype(jnp.float32) * sy_f)
    colsel = ((wiota == rel_c_f).astype(jnp.float32) * (1.0 - sx_f)
              + (wiota == rel_c_f + 1).astype(jnp.float32) * sx_f)
    # MXU: vertical interpolation for the whole tile at once.
    rowmix = jax.lax.dot_general(
        rowsel, get_strip().astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (p, width)
    val = jnp.sum(rowmix * colsel, axis=1)                 # VPU 2-tap blend
    return val.reshape(ty, chunk) * (r * r)


def backproject_kernel(A_ref, img_ref, *refs,
                       o_mm, n_u, n_v, ty, chunk, band, width,
                       quantized=False):
    """One grid step: back-project one projection into a (1, TY, CHUNK)
    volume tile.

    Refs: ``A_ref`` (3,4) f32 in SMEM; ``img_ref`` zero-padded projection
    in ANY/HBM; with ``quantized=True`` a ``(2, rows)`` per-row scale
    block in VMEM follows (``img_ref`` then holds int8 codes); then the
    aliased ``vol_in/out`` volume tile in VMEM, ``strip_ref`` VMEM
    scratch, ``sem`` DMA semaphore.
    """
    scl_ref = None
    if quantized:
        scl_ref, *refs = refs
    vol_in_ref, vol_out_ref, strip_ref, sem = refs
    z = pl.program_id(0)
    y0 = (pl.program_id(1) * ty).astype(jnp.float32)
    x0 = (pl.program_id(2) * chunk).astype(jnp.float32)

    ix, iy, w, r, r0, c0, active = _tile_geometry(
        _read_A(A_ref), o_mm, z, y0, x0, n_u=n_u, n_v=n_v, ty=ty,
        chunk=chunk, band=band, width=width, pad_rows=img_ref.shape[0],
        pad_cols=img_ref.shape[1])

    @pl.when(active)
    def _():
        # --- Part 2: one strip DMA replaces 4*TY*CHUNK gathers ----------
        copy = pltpu.make_async_copy(
            img_ref.at[pl.ds(r0, band), pl.ds(c0, width)], strip_ref, sem)
        copy.start()

        def strip():
            copy.wait()
            return _dequant_strip(strip_ref[...], scl_ref, r0, band)

        contrib = _tile_contrib(strip, ix, iy, r, r0, c0,
                                ty=ty, chunk=chunk, band=band, width=width)
        # --- Part 3: inverse-square-law weighted accumulate -------------
        vol_out_ref[...] = vol_in_ref[...] + contrib.astype(
            vol_in_ref.dtype)[None]

    @pl.when(jnp.logical_not(active))
    def _():
        vol_out_ref[...] = vol_in_ref[...]


def _micro_tile_accumulate(wait_strip, read_window, update, ix, iy, r, *,
                           r0, c0, ty, chunk, band, width, group, gband,
                           gwidth):
    """Parts 2+3 per ``group``-voxel micro-window against a resident
    strip — the one implementation the single-projection micro kernel and
    the batched micro variant share, so the planner-validated
    ``(micro_band, micro_width)`` window semantics exist exactly once.

    ``wait_strip`` blocks on the strip DMA (called once the per-voxel tap
    coordinates are built, so the copy overlaps the selector
    arithmetic); ``read_window(r0g, c0g)`` returns the ``(gband,
    gwidth)`` sub-window at an in-strip origin; ``update(row, col,
    val)`` folds one group's ``(group,)`` f32 contribution into the
    accumulation target at tile row ``row``, columns ``[col, col +
    group)``.
    """
    fx = jnp.floor(ix)
    fy = jnp.floor(iy)
    sx = (ix - fx).reshape(ty * chunk)
    sy = (iy - fy).reshape(ty * chunk)
    rel_r = (fy.astype(jnp.int32) + 1 - r0).reshape(ty * chunk)
    rel_c = (fx.astype(jnp.int32) + 1 - c0).reshape(ty * chunk)
    rw2 = (r * r).reshape(ty * chunk)

    wait_strip()
    n_groups = (ty * chunk) // group
    cols_per_row = chunk // group

    biota = jax.lax.broadcasted_iota(jnp.int32, (group, gband), 1)
    wiota = jax.lax.broadcasted_iota(jnp.int32, (group, gwidth), 1)

    def one_group(g, _):
        gs_ = g * group
        rr = jax.lax.dynamic_slice(rel_r, (gs_,), (group,))
        cc = jax.lax.dynamic_slice(rel_c, (gs_,), (group,))
        sxg = jax.lax.dynamic_slice(sx, (gs_,), (group,))
        syg = jax.lax.dynamic_slice(sy, (gs_,), (group,))
        wg = jax.lax.dynamic_slice(rw2, (gs_,), (group,))
        # Window origin from the *in-strip* tap positions only (far
        # out-of-detector voxels would otherwise drag the window off
        # the contributing taps; their own one-hots are zero either
        # way).
        r0g = jnp.clip(jnp.min(jnp.clip(rr, 0, band - 1)),
                       0, band - gband)
        c0g = jnp.clip(jnp.min(jnp.clip(cc, 0, width - 1)),
                       0, width - gwidth)
        win = read_window(r0g, c0g)
        rowsel = ((biota == (rr - r0g)[:, None]).astype(jnp.float32)
                  * (1.0 - syg[:, None])
                  + (biota == (rr - r0g)[:, None] + 1).astype(
                      jnp.float32) * syg[:, None])
        colsel = ((wiota == (cc - c0g)[:, None]).astype(jnp.float32)
                  * (1.0 - sxg[:, None])
                  + (wiota == (cc - c0g)[:, None] + 1).astype(
                      jnp.float32) * sxg[:, None])
        mix = jax.lax.dot_general(
            rowsel, win.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (group, gwidth)
        val = jnp.sum(mix * colsel, axis=1) * wg
        update(gs_ // chunk, (g % cols_per_row) * group, val)
        return 0

    jax.lax.fori_loop(0, n_groups, one_group, 0)


def backproject_kernel_micro(A_ref, img_ref, *refs,
                             o_mm, n_u, n_v, ty, chunk, band, width,
                             group, gband, gwidth, quantized=False):
    """Micro-window variant (hillclimb CT-5): strip DMA as usual, but the
    tap selection runs per ``group``-voxel micro-window instead of one
    tile-wide banded matmul.

    The plain kernel's rowsel matmul costs ``2 * band * width`` flops per
    voxel (16k at production size) because every voxel's one-hot row
    spans the whole strip.  Within a group of 8 consecutive voxels the
    taps span only ~``group * du`` columns and ~2 rows, so a
    ``(gband, gwidth)`` VMEM sub-slice + tiny selects bring it down to
    ``~2 * gband * gwidth`` (256) flops per voxel — the same napkin math
    as the jnp ``strip2`` strategy, now at kernel level where the strip
    load is a DMA rather than an XLA gather.
    """
    scl_ref = None
    if quantized:
        scl_ref, *refs = refs
    vol_in_ref, vol_out_ref, strip_ref, sem = refs
    z = pl.program_id(0)
    y0 = (pl.program_id(1) * ty).astype(jnp.float32)
    x0 = (pl.program_id(2) * chunk).astype(jnp.float32)

    ix, iy, w, r, r0, c0, active = _tile_geometry(
        _read_A(A_ref), o_mm, z, y0, x0, n_u=n_u, n_v=n_v, ty=ty,
        chunk=chunk, band=band, width=width, pad_rows=img_ref.shape[0],
        pad_cols=img_ref.shape[1])

    @pl.when(active)
    def _():
        copy = pltpu.make_async_copy(
            img_ref.at[pl.ds(r0, band), pl.ds(c0, width)], strip_ref,
            sem)
        copy.start()

        def update(row, col, val):
            cur = vol_in_ref[0, row, pl.ds(col, group)]
            vol_out_ref[0, row, pl.ds(col, group)] = \
                cur + val.astype(vol_in_ref.dtype)

        _micro_tile_accumulate(
            copy.wait,
            # Dequant per micro-window at its *global* row origin
            # r0 + r0g — the scale block indexes padded detector rows.
            lambda r0g, c0g: _dequant_strip(
                strip_ref[pl.ds(r0g, gband), pl.ds(c0g, gwidth)],
                scl_ref, r0 + r0g, gband),
            update, ix, iy, r, r0=r0, c0=c0, ty=ty, chunk=chunk,
            band=band, width=width, group=group, gband=gband,
            gwidth=gwidth)

    @pl.when(jnp.logical_not(active))
    def _():
        vol_out_ref[...] = vol_in_ref[...]


def backproject_kernel_db(A_ref, img_ref, *refs,
                          o_mm, n_u, n_v, ty, chunk, band, width,
                          grid_dims, depth=2, quantized=False):
    """Double-buffered variant: the strip DMA for grid step ``k+1`` is
    issued before step ``k``'s compute (hillclimb CT-3), generalised to
    a ``depth``-slot rotation running ``depth - 1`` fetches ahead.

    KNC had no usable gather prefetch (the paper found
    ``vgatherpf0dps`` blocking and scalar prefetch too expensive,
    section 6.4); on TPU the strip origin is *computed* geometry, so
    future tiles' DMAs can be launched any number of steps ahead into a
    ``(depth, band, width)`` scratch — compute and DMA overlap with
    zero extra instructions on the critical path.  Step 0 primes the
    first ``depth - 1`` fetches; step ``k`` then issues the fetch for
    step ``k + depth - 1`` (whose slot was drained at step ``k - 1``)
    and waits on its own — the same rotation ledger as the batched
    :func:`backproject_kernel_batch_db` at ``pbatch = 1``, so a tuned
    ``db_depth`` means one thing on both paths.

    Both the prefetch *and* this step's own strip address come from the
    corner-based :func:`_strip_origin` (the full Part-1 pass previously
    rerun per prefetch computed ``ix/iy/w/r`` for the whole next tile
    just to floor two minima), so producer and consumer agree by
    construction.
    """
    scl_ref = None
    if quantized:
        scl_ref, *refs = refs
    vol_in_ref, vol_out_ref, strip_ref, sems = refs
    nz, ny, nc = grid_dims
    z = pl.program_id(0)
    yb = pl.program_id(1)
    cb = pl.program_id(2)
    step = (z * ny + yb) * nc + cb
    total = nz * ny * nc
    slot = jax.lax.rem(step, depth)

    pad_rows = img_ref.shape[0]
    pad_cols = img_ref.shape[1]
    A = _read_A(A_ref)

    def origin(zi, yi, ci):
        return _strip_origin(
            A, o_mm, zi, (yi * ty).astype(jnp.float32),
            (ci * chunk).astype(jnp.float32), n_u=n_u, n_v=n_v, ty=ty,
            chunk=chunk, band=band, width=width, pad_rows=pad_rows,
            pad_cols=pad_cols)

    def start_dma(t):
        cn = jax.lax.rem(t, nc)
        rest = jax.lax.div(t, nc)
        yn = jax.lax.rem(rest, ny)
        zn = jax.lax.div(rest, ny)
        r0n, c0n = origin(zn, yn, cn)
        s = jax.lax.rem(t, depth)
        pltpu.make_async_copy(
            img_ref.at[pl.ds(r0n, band), pl.ds(c0n, width)],
            strip_ref.at[s], sems.at[s]).start()

    ix, iy, w, r = _part1_tile(A, o_mm, z, (yb * ty).astype(jnp.float32),
                               (cb * chunk).astype(jnp.float32), ty, chunk)
    active = _tile_active(ix, iy, w, n_u, n_v)
    r0, c0 = origin(z, yb, cb)

    # First step primes the whole lookahead window.
    @pl.when(step == 0)
    def _():
        for d in range(min(depth - 1, total)):
            start_dma(jnp.int32(d))

    # Refill the slot step-1 just drained with step + depth - 1's strip.
    @pl.when(step + (depth - 1) < total)
    def _():
        start_dma(step + (depth - 1))

    def wait_strip():
        pltpu.make_async_copy(
            img_ref.at[pl.ds(r0, band), pl.ds(c0, width)],
            strip_ref.at[slot], sems.at[slot]).wait()

    @pl.when(active)
    def _():
        def strip():
            wait_strip()
            return _dequant_strip(strip_ref[slot], scl_ref, r0, band)

        contrib = _tile_contrib(strip, ix, iy, r, r0, c0,
                                ty=ty, chunk=chunk, band=band, width=width)
        vol_out_ref[...] = vol_in_ref[...] + contrib.astype(
            vol_in_ref.dtype)[None]

    @pl.when(jnp.logical_not(active))
    def _():
        # The prefetched strip for this inactive tile must still be
        # consumed so the semaphore balances.
        wait_strip()
        vol_out_ref[...] = vol_in_ref[...]


def _batch_strip_loop(A_ref, imgs_ref, strip_ref, sems, consume, *,
                      o_mm, n_u, n_v, ty, chunk, band, width, pbatch,
                      z, y0, x0):
    """The per-projection strip pipeline the plain and micro batch
    kernels share — one DMA ledger, two compute schemes.

    Per in-kernel projection ``p``: projection ``p+1``'s strip (address
    from the corner-based :func:`_strip_origin`) is prefetched into the
    other half of a 2-slot rotation while ``p``'s contribution computes
    — the CT-3 trick applied where it pays most.  Every projection's
    strip is DMA'd and waited unconditionally (clamped origins are
    always in-bounds) so the semaphores balance; off-detector
    projections contribute zero through the all-zero one-hot rows and
    the ``r²`` mask.  ``consume(p, slot, wait_strip, ix, iy, r, r0,
    c0)`` runs under the active flag and folds projection ``p``'s
    contribution into the caller's accumulator (calling ``wait_strip``
    once its selectors are built, so the copy overlaps them; ``p`` lets
    the int8 consumers pick projection ``p``'s scale rows).
    """
    pad_rows = imgs_ref.shape[1]
    pad_cols = imgs_ref.shape[2]

    def origin(p):
        return _strip_origin(
            _read_A(A_ref, p), o_mm, z, y0, x0, n_u=n_u, n_v=n_v, ty=ty,
            chunk=chunk, band=band, width=width, pad_rows=pad_rows,
            pad_cols=pad_cols)

    def start_dma(p, r0, c0, slot):
        pltpu.make_async_copy(
            imgs_ref.at[p, pl.ds(r0, band), pl.ds(c0, width)],
            strip_ref.at[slot], sems.at[slot]).start()

    r0_first, c0_first = origin(0)
    start_dma(0, r0_first, c0_first, 0)

    def body(p, carry):
        r0, c0 = carry                 # projection p's strip (in flight)
        slot = jax.lax.rem(p, 2)

        # Prefetch projection p+1's strip into the other slot while p's
        # contribution computes.  The clamped index keeps the SMEM read
        # in-bounds on the last iteration; the DMA only starts when a
        # next projection exists.
        pn = jnp.minimum(p + 1, pbatch - 1)
        r0n, c0n = origin(pn)

        @pl.when(p + 1 < pbatch)
        def _():
            start_dma(pn, r0n, c0n, 1 - slot)

        ix, iy, w, r = _part1_tile(_read_A(A_ref, p), o_mm, z, y0, x0,
                                   ty, chunk)
        active = _tile_active(ix, iy, w, n_u, n_v)

        def wait_strip():
            pltpu.make_async_copy(
                imgs_ref.at[p, pl.ds(r0, band), pl.ds(c0, width)],
                strip_ref.at[slot], sems.at[slot]).wait()

        @pl.when(active)
        def _():
            consume(p, slot, wait_strip, ix, iy, r, r0, c0)

        @pl.when(jnp.logical_not(active))
        def _():
            wait_strip()               # balance the unconditional DMA

        return (r0n, c0n)

    jax.lax.fori_loop(0, pbatch, body, (r0_first, c0_first))


def backproject_kernel_batch(A_ref, imgs_ref, *refs,
                             o_mm, n_u, n_v, ty, chunk, band, width,
                             pbatch, quantized=False):
    """Projection-batched grid step: the ``(1, ty, chunk)`` volume tile
    stays resident in VMEM while an in-kernel ``fori_loop`` folds in
    ``pbatch`` projections — the inverted loop nest (DESIGN.md §7).

    Refs: ``A_ref`` stacked ``(pbatch, 3, 4)`` f32 in SMEM; ``imgs_ref``
    stacked zero-padded projections ``(pbatch, rows, cols)`` in ANY/HBM;
    with ``quantized=True`` a ``(pbatch, 2, rows)`` scale block in VMEM
    follows (``imgs_ref`` then holds int8 codes); then the aliased
    ``vol_in/out`` volume tile, ``strip_ref`` ``(2, band, width)`` VMEM
    scratch, ``acc_ref`` ``(ty, chunk)`` f32 accumulator, ``sems`` 2
    DMA semaphores.

    The volume tile is loaded once and stored once per ``pbatch``
    projections — volume HBM traffic drops by the batch factor versus
    the per-projection kernels.  The strip DMA discipline lives in
    :func:`_batch_strip_loop` (shared with the micro variant).
    """
    scl_ref = None
    if quantized:
        scl_ref, *refs = refs
    vol_in_ref, vol_out_ref, strip_ref, acc_ref, sems = refs
    z = pl.program_id(0)
    y0 = (pl.program_id(1) * ty).astype(jnp.float32)
    x0 = (pl.program_id(2) * chunk).astype(jnp.float32)

    acc_ref[...] = vol_in_ref[0].astype(jnp.float32)

    def consume(p, slot, wait_strip, ix, iy, r, r0, c0):
        def strip():
            wait_strip()
            return _dequant_strip(strip_ref[slot], scl_ref, r0, band, p)

        acc_ref[...] += _tile_contrib(
            strip, ix, iy, r, r0, c0, ty=ty, chunk=chunk, band=band,
            width=width)

    _batch_strip_loop(A_ref, imgs_ref, strip_ref, sems, consume,
                      o_mm=o_mm, n_u=n_u, n_v=n_v, ty=ty, chunk=chunk,
                      band=band, width=width, pbatch=pbatch, z=z, y0=y0,
                      x0=x0)
    vol_out_ref[...] = acc_ref[...].astype(vol_out_ref.dtype)[None]


def backproject_kernel_batch_db(A_ref, imgs_ref, *refs,
                                o_mm, n_u, n_v, ty, chunk, band, width,
                                pbatch, depth, grid_dims, quantized=False):
    """Deep-pipelined batched grid step: the strip DMA stream runs
    ``depth - 1`` fetches ahead of compute through a ``depth``-slot
    rotation, across *both* the in-kernel projection ``fori_loop`` and
    the plane/tile grid loop.

    The plain batch kernel's pipeline drains at every grid-step
    boundary: projection 0 of tile ``k+1`` only starts its DMA once tile
    ``k`` is fully folded, so each of the ``nz·ny·nc`` steps eats one
    cold strip latency.  Here every strip fetch lives on one global
    sequence ``t = step·pbatch + p``; iteration ``t`` issues the DMA for
    ``t + depth - 1`` (its target slot was consumed at iteration
    ``t - 1``, so the rotation never overwrites a live strip) and the
    strip addresses of *future tiles* are plain geometry via the
    corner-based :func:`_strip_origin` — nothing about a tile has to be
    resident to prefetch for it.  ``depth=2`` is the classical double
    buffer without the per-step drain; deeper pipelines keep more
    fetches in flight (the ROADMAP's "in-flight depth > 2" item), which
    pays once a single strip latency exceeds one projection's compute.

    Refs as :func:`backproject_kernel_batch`, except ``strip_ref`` is
    ``(depth, band, width)`` and ``sems`` ``depth`` DMA semaphores.
    Issue/wait counts balance by construction: exactly one DMA is
    issued and one waited per sequence index (`t < total` guards both
    ends), and every wait recomputes the same origin the issuer used.
    """
    scl_ref = None
    if quantized:
        scl_ref, *refs = refs
    vol_in_ref, vol_out_ref, strip_ref, acc_ref, sems = refs
    nz, ny, nc = grid_dims
    z = pl.program_id(0)
    yb = pl.program_id(1)
    cb = pl.program_id(2)
    step = (z * ny + yb) * nc + cb
    t0 = step * pbatch
    total = nz * ny * nc * pbatch
    y0 = (yb * ty).astype(jnp.float32)
    x0 = (cb * chunk).astype(jnp.float32)
    pad_rows = imgs_ref.shape[1]
    pad_cols = imgs_ref.shape[2]

    def origin(A, zi, yi, xi):
        return _strip_origin(A, o_mm, zi, yi, xi, n_u=n_u, n_v=n_v, ty=ty,
                             chunk=chunk, band=band, width=width,
                             pad_rows=pad_rows, pad_cols=pad_cols)

    def start_dma(t):
        """Issue the strip fetch for global sequence index ``t`` —
        decode (tile, projection), compute the corner origin, copy into
        slot ``t % depth``."""
        s = jax.lax.div(t, pbatch)
        p = jax.lax.rem(t, pbatch)
        cn = jax.lax.rem(s, nc)
        rest = jax.lax.div(s, nc)
        yn = jax.lax.rem(rest, ny)
        zn = jax.lax.div(rest, ny)
        r0, c0 = origin(_read_A(A_ref, p), zn,
                        (yn * ty).astype(jnp.float32),
                        (cn * chunk).astype(jnp.float32))
        slot = jax.lax.rem(t, depth)
        pltpu.make_async_copy(
            imgs_ref.at[p, pl.ds(r0, band), pl.ds(c0, width)],
            strip_ref.at[slot], sems.at[slot]).start()

    # The first step primes the whole lookahead window; later steps
    # inherit their leading strips from their predecessors' prefetches.
    @pl.when(step == 0)
    def _():
        for d in range(min(depth - 1, total)):
            start_dma(jnp.int32(d))

    acc_ref[...] = vol_in_ref[0].astype(jnp.float32)

    def body(p, _):
        t = t0 + p
        # Refill the slot iteration t-1 just drained with strip
        # t + depth - 1 (possibly a future tile's) before this
        # iteration's compute, so the copy overlaps it.
        @pl.when(t + (depth - 1) < total)
        def _():
            start_dma(t + (depth - 1))

        A = _read_A(A_ref, p)
        ix, iy, w, r = _part1_tile(A, o_mm, z, y0, x0, ty, chunk)
        active = _tile_active(ix, iy, w, n_u, n_v)
        # t always belongs to *this* tile, so its origin is current-tile
        # geometry — the issuer (iteration t - depth + 1) computed the
        # identical corner origin, producer and consumer agreeing by
        # construction.
        r0, c0 = origin(A, z, y0, x0)
        slot = jax.lax.rem(t, depth)

        def wait_strip():
            pltpu.make_async_copy(
                imgs_ref.at[p, pl.ds(r0, band), pl.ds(c0, width)],
                strip_ref.at[slot], sems.at[slot]).wait()

        @pl.when(active)
        def _():
            def strip():
                wait_strip()
                return _dequant_strip(strip_ref[slot], scl_ref, r0,
                                      band, p)

            acc_ref[...] += _tile_contrib(
                strip, ix, iy, r, r0, c0, ty=ty, chunk=chunk, band=band,
                width=width)

        @pl.when(jnp.logical_not(active))
        def _():
            wait_strip()               # balance the unconditional DMA
        return 0

    jax.lax.fori_loop(0, pbatch, body, 0)
    vol_out_ref[...] = acc_ref[...].astype(vol_out_ref.dtype)[None]


def backproject_kernel_batch_micro(A_ref, imgs_ref, *refs,
                                   o_mm, n_u, n_v, ty, chunk, band,
                                   width, pbatch, group, gband, gwidth,
                                   quantized=False):
    """Micro-window batched grid step: the volume tile stays resident
    across the in-kernel projection loop exactly as in
    :func:`backproject_kernel_batch` (same strip DMA double-buffering,
    same corner-based origins), but Parts 2+3 run per ``group``-voxel
    ``(gband, gwidth)`` micro-window through the shared
    :func:`_micro_tile_accumulate` — the CT-5 flop cut applied on top of
    the §7 traffic cut, so the tuner's fastest single-projection compute
    scheme no longer has to give up the batched path's volume locality.
    """
    scl_ref = None
    if quantized:
        scl_ref, *refs = refs
    vol_in_ref, vol_out_ref, strip_ref, acc_ref, sems = refs
    z = pl.program_id(0)
    y0 = (pl.program_id(1) * ty).astype(jnp.float32)
    x0 = (pl.program_id(2) * chunk).astype(jnp.float32)

    acc_ref[...] = vol_in_ref[0].astype(jnp.float32)

    def consume(p, slot, wait_strip, ix, iy, r, r0, c0):
        def update(row, col, val):
            cur = acc_ref[row, pl.ds(col, group)]
            acc_ref[row, pl.ds(col, group)] = cur + val

        _micro_tile_accumulate(
            wait_strip,
            lambda r0g, c0g: _dequant_strip(
                strip_ref[slot, pl.ds(r0g, gband), pl.ds(c0g, gwidth)],
                scl_ref, r0 + r0g, gband, p),
            update, ix, iy, r, r0=r0, c0=c0, ty=ty, chunk=chunk,
            band=band, width=width, group=group, gband=gband,
            gwidth=gwidth)

    _batch_strip_loop(A_ref, imgs_ref, strip_ref, sems, consume,
                      o_mm=o_mm, n_u=n_u, n_v=n_v, ty=ty, chunk=chunk,
                      band=band, width=width, pbatch=pbatch, z=z, y0=y0,
                      x0=x0)
    vol_out_ref[...] = acc_ref[...].astype(vol_out_ref.dtype)[None]


def backproject_kernel_batch_shared(A_ref, imgs_ref, *refs,
                                    o_mm, n_u, n_v, ty, chunk, band,
                                    width, pbatch, quantized=False):
    """Shared-superset-window batched grid step: ONE window DMA per
    (volume tile, projection group) instead of ``pbatch`` strip fetches.

    Adjacent angles' strips over one tile overlap heavily, so the group
    is served from a single superset window anchored at the elementwise
    *minimum* of the members' corner origins (:func:`_strip_origin` per
    projection; each is already clamped in-bounds, so the minimum is
    too).  The DMA moves a ``(pbatch, band, width)`` slab — same total
    pixel area only when the members coincide, but always a ``pbatch``×
    cut in DMA *descriptors*, and strictly fewer bytes than ``pbatch``
    fetches of the same ``(band, width)`` whenever the superset dims are
    tighter than ``pbatch`` disjoint windows would need.  Coverage is
    NOT checked here: ops.py sizes/validates ``(band, width)`` against
    the host planner's :func:`repro.core.clipping
    .shared_window_requirement` — an undersized window would drop taps
    silently, so the wrapper raises before this kernel ever runs.

    Refs as :func:`backproject_kernel_batch`, except the scratch is one
    ``(pbatch, band, width)`` window slab and a single DMA semaphore.
    """
    scl_ref = None
    if quantized:
        scl_ref, *refs = refs
    vol_in_ref, vol_out_ref, win_ref, acc_ref, sem = refs
    z = pl.program_id(0)
    y0 = (pl.program_id(1) * ty).astype(jnp.float32)
    x0 = (pl.program_id(2) * chunk).astype(jnp.float32)
    pad_rows = imgs_ref.shape[1]
    pad_cols = imgs_ref.shape[2]

    r0s = c0s = None
    for p in range(pbatch):
        r0p, c0p = _strip_origin(
            _read_A(A_ref, p), o_mm, z, y0, x0, n_u=n_u, n_v=n_v, ty=ty,
            chunk=chunk, band=band, width=width, pad_rows=pad_rows,
            pad_cols=pad_cols)
        r0s = r0p if r0s is None else jnp.minimum(r0s, r0p)
        c0s = c0p if c0s is None else jnp.minimum(c0s, c0p)

    copy = pltpu.make_async_copy(
        imgs_ref.at[pl.ds(0, pbatch), pl.ds(r0s, band), pl.ds(c0s, width)],
        win_ref, sem)
    copy.start()
    acc_ref[...] = vol_in_ref[0].astype(jnp.float32)   # overlaps the DMA
    copy.wait()

    def body(p, _):
        ix, iy, w, r = _part1_tile(_read_A(A_ref, p), o_mm, z, y0, x0,
                                   ty, chunk)
        active = _tile_active(ix, iy, w, n_u, n_v)

        @pl.when(active)
        def _():
            acc_ref[...] += _tile_contrib(
                lambda: _dequant_strip(win_ref[p], scl_ref, r0s, band, p),
                ix, iy, r, r0s, c0s, ty=ty,
                chunk=chunk, band=band, width=width)
        return 0

    jax.lax.fori_loop(0, pbatch, body, 0)
    vol_out_ref[...] = acc_ref[...].astype(vol_out_ref.dtype)[None]


def backproject_volume_pallas(volume, padded_img, A, *, o_mm, n_u, n_v,
                              ty=8, chunk=128, band=16, width=512,
                              double_buffer=False, db_depth=2,
                              micro=False, micro_group=8, micro_band=8,
                              micro_width=32, scales=None,
                              interpret=False):
    """``pallas_call`` wrapper: one projection into the whole volume.

    ``volume``: (L, L, L) f32; ``padded_img``: zero-padded projection,
    row/col counts already rounded up by ops.py so ``band``/``width``
    slices always fit.  Returns the updated volume (input aliased).
    ``double_buffer=True`` selects the DMA-prefetching variant (CT-3;
    ``db_depth`` slots in rotation, same ledger as the batched variant);
    ``micro=True`` the per-group micro-window compute (CT-5).

    ``scales`` selects the int8 wire: ``padded_img`` holds int8 codes
    and ``scales`` the ``(2, rows)`` f32 per-row scale/offset block
    (built by ops.py from :func:`repro.quant.quantize_rows`), kept
    VMEM-resident for the whole call via a constant-index BlockSpec —
    it is ~8 bytes per detector row against the strip stream it
    sidesteps, so it is fetched once, not per window.

    (``micro_band`` used to default to 4 — the same silent tap-drop
    hazard class PR 2 fixed for the jnp ``strip2`` ``gband``; 8 covers
    every geometry in the repo's sweeps, and ops.py now validates the
    micro window against the host planner.)
    """
    L = volume.shape[0]
    assert L % ty == 0 and L % chunk == 0
    grid = (L, L // ty, L // chunk)
    quantized = scales is not None

    vol_spec = pl.BlockSpec((1, ty, chunk), lambda z, y, x: (z, y, x))
    if micro and double_buffer:
        raise ValueError(
            "kernel variants are exclusive: got micro=True and "
            "double_buffer=True; a tuned decision names exactly one")
    if micro:
        kernel = functools.partial(
            backproject_kernel_micro, o_mm=o_mm, n_u=n_u, n_v=n_v,
            ty=ty, chunk=chunk, band=band, width=width,
            group=micro_group, gband=micro_band, gwidth=micro_width,
            quantized=quantized)
        scratch = [pltpu.VMEM((band, width), padded_img.dtype),
                   pltpu.SemaphoreType.DMA]
        name = "backproject_strip_micro"
    elif double_buffer:
        depth = int(db_depth)
        if depth < 2:
            raise ValueError(
                f"db_depth={db_depth}: the pipelined kernel needs an "
                f"in-flight slot rotation of at least 2")
        kernel = functools.partial(
            backproject_kernel_db, o_mm=o_mm, n_u=n_u, n_v=n_v,
            ty=ty, chunk=chunk, band=band, width=width, grid_dims=grid,
            depth=depth, quantized=quantized)
        scratch = [pltpu.VMEM((depth, band, width), padded_img.dtype),
                   pltpu.SemaphoreType.DMA((depth,))]
        name = f"backproject_strip_db{depth}"
    else:
        kernel = functools.partial(
            backproject_kernel, o_mm=o_mm, n_u=n_u, n_v=n_v,
            ty=ty, chunk=chunk, band=band, width=width,
            quantized=quantized)
        scratch = [pltpu.VMEM((band, width), padded_img.dtype),
                   pltpu.SemaphoreType.DMA]
        name = "backproject_strip"

    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),       # A (3, 4)
        pl.BlockSpec(memory_space=pltpu.ANY),        # padded image (HBM)
    ]
    args = [A, padded_img]
    if quantized:
        # Whole scale block resident in VMEM (constant index map).
        in_specs.append(pl.BlockSpec(scales.shape, lambda z, y, x: (0, 0)))
        args.append(scales)
        name += "_int8"
    in_specs.append(vol_spec)                        # volume tile in
    args.append(volume)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=vol_spec,
        out_shape=jax.ShapeDtypeStruct(volume.shape, volume.dtype),
        scratch_shapes=scratch,
        input_output_aliases={len(args) - 1: 0},
        interpret=interpret,
        name=name,
    )(*args)


def backproject_volume_pallas_batch(volume, padded_imgs, A_stack, *, o_mm,
                                    n_u, n_v, ty=8, chunk=128, band=16,
                                    width=512, double_buffer=False,
                                    db_depth=2, micro=False, micro_group=8,
                                    micro_band=8, micro_width=32,
                                    shared_window=False, scales=None,
                                    interpret=False):
    """``pallas_call`` wrapper: one *batch* of projections into the whole
    volume, volume tile resident across the in-kernel projection loop.

    ``padded_imgs``: stacked zero-padded projections ``(pbatch, rows,
    cols)`` (rows/cols already rounded up by ops.py); ``A_stack``:
    ``(pbatch, 3, 4)`` matrices.  Returns the updated volume (input
    aliased).  Volume HBM traffic per call: one load + one store of
    ``L³`` — a ``pbatch``× cut versus ``pbatch`` calls of
    :func:`backproject_volume_pallas`.

    Variants mirror the single-projection wrapper: ``micro=True``
    selects the per-group micro-window compute (CT-5) on the batched
    nest; ``double_buffer=True`` the deep DMA pipeline
    (:func:`backproject_kernel_batch_db`, ``db_depth`` slots in
    rotation, in-flight depth ``db_depth - 1`` across the plane loop);
    ``shared_window=True`` the one-DMA-per-group superset-window scheme
    (:func:`backproject_kernel_batch_shared` — here ``band``/``width``
    are the *superset* dims ops.py sized against the group planner).
    The variants are exclusive — asking for two raises rather than
    silently preferring one, because a tuned decision named exactly one.

    ``scales`` selects the int8 wire exactly as in
    :func:`backproject_volume_pallas`, stacked ``(pbatch, 2, rows)``.
    """
    L = volume.shape[0]
    pbatch = int(A_stack.shape[0])
    assert L % ty == 0 and L % chunk == 0
    assert padded_imgs.shape[0] == pbatch
    grid = (L, L // ty, L // chunk)
    quantized = scales is not None

    vol_spec = pl.BlockSpec((1, ty, chunk), lambda z, y, x: (z, y, x))
    if micro and double_buffer or shared_window and (micro or double_buffer):
        raise ValueError(
            f"batch kernel variants are exclusive: got micro={micro}, "
            f"double_buffer={double_buffer}, shared_window="
            f"{shared_window}; a tuned decision names exactly one")

    def specs_and_args():
        in_specs = [
            pl.BlockSpec(memory_space=pltpu.SMEM),   # A stack (P, 3, 4)
            pl.BlockSpec(memory_space=pltpu.ANY),    # padded images (HBM)
        ]
        args = [A_stack, padded_imgs]
        if quantized:
            # Whole (P, 2, rows) scale block VMEM-resident per call.
            in_specs.append(
                pl.BlockSpec(scales.shape, lambda z, y, x: (0, 0, 0)))
            args.append(scales)
        in_specs.append(vol_spec)                    # volume tile in
        args.append(volume)
        return in_specs, args

    if shared_window:
        kernel = functools.partial(
            backproject_kernel_batch_shared, o_mm=o_mm, n_u=n_u, n_v=n_v,
            ty=ty, chunk=chunk, band=band, width=width, pbatch=pbatch,
            quantized=quantized)
        in_specs, args = specs_and_args()
        name = f"backproject_strip_batch_shared_p{pbatch}"
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=vol_spec,
            out_shape=jax.ShapeDtypeStruct(volume.shape, volume.dtype),
            scratch_shapes=[
                pltpu.VMEM((pbatch, band, width), padded_imgs.dtype),
                pltpu.VMEM((ty, chunk), jnp.float32),
                pltpu.SemaphoreType.DMA,
            ],
            input_output_aliases={len(args) - 1: 0},
            interpret=interpret,
            name=name + ("_int8" if quantized else ""),
        )(*args)
    if micro:
        kernel = functools.partial(
            backproject_kernel_batch_micro, o_mm=o_mm, n_u=n_u, n_v=n_v,
            ty=ty, chunk=chunk, band=band, width=width, pbatch=pbatch,
            group=micro_group, gband=micro_band, gwidth=micro_width,
            quantized=quantized)
        n_slots = 2
        name = f"backproject_strip_batch_micro_p{pbatch}"
    elif double_buffer:
        n_slots = int(db_depth)
        if n_slots < 2:
            raise ValueError(
                f"db_depth={db_depth}: the pipelined batch kernel needs "
                f"an in-flight slot rotation of at least 2")
        kernel = functools.partial(
            backproject_kernel_batch_db, o_mm=o_mm, n_u=n_u, n_v=n_v,
            ty=ty, chunk=chunk, band=band, width=width, pbatch=pbatch,
            depth=n_slots, grid_dims=grid, quantized=quantized)
        name = f"backproject_strip_batch_db{n_slots}_p{pbatch}"
    else:
        kernel = functools.partial(
            backproject_kernel_batch, o_mm=o_mm, n_u=n_u, n_v=n_v,
            ty=ty, chunk=chunk, band=band, width=width, pbatch=pbatch,
            quantized=quantized)
        n_slots = 2
        name = f"backproject_strip_batch_p{pbatch}"
    in_specs, args = specs_and_args()
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=vol_spec,
        out_shape=jax.ShapeDtypeStruct(volume.shape, volume.dtype),
        scratch_shapes=[
            pltpu.VMEM((n_slots, band, width), padded_imgs.dtype),
            pltpu.VMEM((ty, chunk), jnp.float32),
            pltpu.SemaphoreType.DMA((n_slots,)),
        ],
        input_output_aliases={len(args) - 1: 0},
        interpret=interpret,
        name=name + ("_int8" if quantized else ""),
    )(*args)
