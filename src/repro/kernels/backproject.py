"""Pallas TPU kernel: strip-blocked cone-beam back projection.

The TPU-native re-think of the paper's fastest CPU scheme (AVX/FMA3
"pairwise loads beat hardware gather", section 6.1), built from three
mechanisms the x86 kernels could only approximate:

1. **Strip DMA instead of gather** — per grid step the kernel computes the
   detector footprint of its ``(TY, CHUNK)`` voxel tile *in-kernel* (Part 1
   on the VPU), then issues one ``make_async_copy`` HBM->VMEM block copy of
   the minimal ``(band, width)`` strip.  One DMA descriptor replaces
   ``4 * TY * CHUNK`` scattered loads: this is the pairwise-load idea at
   DMA granularity.
2. **MXU as texture unit** — the vertical interpolation is a banded
   one-hot matmul ``rowsel(P, band) @ strip(band, width)`` on the MXU; the
   horizontal 2-tap selection runs as iota-compare/select on the VPU.
   Out-of-band one-hot rows are identically zero, which (with the 1-pixel
   zero border added by ops.py) gives exact zero-outside-detector
   semantics with *no* per-tap conditionals — the paper's zero-padded
   buffer trick (section 5.1.1).
3. **Grid pipelining instead of SMT** — KNC needed 4-way SMT to hide
   gather latency and still failed (section 6.4); here the volume-tile
   loads/stores are pipelined by the Pallas grid machinery, and the strip
   DMA for step ``k+1`` can be issued during step ``k``'s compute
   (double-buffered variant, ``double_buffer=True`` — hillclimb CT-2 in
   EXPERIMENTS.md).

Semantics are identical to ``repro.core.backproject.sample_scalar`` +
``accumulate`` (floor bilinear, zero outside, ``1/w^2`` weighting), which
is the oracle in ``backproject_ref.py``; correctness requires
``band``/``width`` to cover each tile's footprint (guaranteed by the
host-side planner in ``repro.core.clipping`` — ops.py checks it).

VMEM budget per step (defaults TY=8, CHUNK=128, band=16, width=512, f32):
strip 32 KB (x2 when double-buffered) + rowmix 2 MB + volume tile 4 KB —
comfortably inside 16 MB, leaving the pipeline room to prefetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["backproject_kernel", "backproject_volume_pallas"]

_EPS_W = 1e-6


def _part1_tile(A_ref, o_mm, z, y0, x0, ty, chunk):
    """Part 1 on the VPU: ICS coords for a (ty, chunk) voxel tile."""
    O, MM = o_mm
    ys = (y0 + jax.lax.broadcasted_iota(jnp.float32, (ty, chunk), 0))
    xs = (x0 + jax.lax.broadcasted_iota(jnp.float32, (ty, chunk), 1))
    wx = O + xs * MM
    wy = O + ys * MM
    wz = O + z.astype(jnp.float32) * MM
    u = wx * A_ref[0, 0] + wy * A_ref[0, 1] + wz * A_ref[0, 2] + A_ref[0, 3]
    v = wx * A_ref[1, 0] + wy * A_ref[1, 1] + wz * A_ref[1, 2] + A_ref[1, 3]
    w = wx * A_ref[2, 0] + wy * A_ref[2, 1] + wz * A_ref[2, 2] + A_ref[2, 3]
    r = jnp.where(w > _EPS_W, 1.0 / w, 0.0)   # reciprocal trick (paper 5.1)
    return u * r, v * r, w, r


def _tile_geometry(A_ref, o_mm, z, y0, x0, *, n_u, n_v, ty, chunk, band,
                   width, pad_rows, pad_cols):
    """Part 1 + strip origin + activity flag for one (ty, chunk) tile."""
    ix, iy, w, r = _part1_tile(A_ref, o_mm, z, y0, x0, ty, chunk)
    ix_c = jnp.clip(ix, -1.0, jnp.float32(n_u))
    iy_c = jnp.clip(iy, -1.0, jnp.float32(n_v))
    r0 = jnp.clip(jnp.floor(jnp.min(iy_c)).astype(jnp.int32),
                  0, pad_rows - band)
    c0 = jnp.clip(jnp.floor(jnp.min(ix_c)).astype(jnp.int32),
                  0, pad_cols - width)
    active = ((jnp.min(ix) < jnp.float32(n_u)) & (jnp.max(ix) > -1.0)
              & (jnp.min(iy) < jnp.float32(n_v)) & (jnp.max(iy) > -1.0)
              & (jnp.max(w) > _EPS_W))
    return ix, iy, w, r, r0, c0, active


def backproject_kernel(A_ref, img_ref, vol_in_ref, vol_out_ref,
                       strip_ref, sem,
                       *, o_mm, n_u, n_v, ty, chunk, band, width):
    """One grid step: back-project one projection into a (1, TY, CHUNK)
    volume tile.

    Refs: ``A_ref`` (3,4) f32 in SMEM; ``img_ref`` zero-padded projection
    in ANY/HBM; ``vol_in/out`` aliased volume tile in VMEM; ``strip_ref``
    VMEM scratch; ``sem`` DMA semaphore.
    """
    z = pl.program_id(0)
    y0 = (pl.program_id(1) * ty).astype(jnp.float32)
    x0 = (pl.program_id(2) * chunk).astype(jnp.float32)

    ix, iy, w, r, r0, c0, active = _tile_geometry(
        A_ref, o_mm, z, y0, x0, n_u=n_u, n_v=n_v, ty=ty, chunk=chunk,
        band=band, width=width, pad_rows=img_ref.shape[0],
        pad_cols=img_ref.shape[1])

    @pl.when(active)
    def _():
        # --- Part 2: one strip DMA replaces 4*TY*CHUNK gathers ----------
        copy = pltpu.make_async_copy(
            img_ref.at[pl.ds(r0, band), pl.ds(c0, width)], strip_ref, sem)
        copy.start()

        fx = jnp.floor(ix)
        fy = jnp.floor(iy)
        sx = ix - fx
        sy = iy - fy
        # Padded-relative tap coordinates (+1: pad offset).
        rel_r = fy.astype(jnp.int32) + 1 - r0
        rel_c = fx.astype(jnp.int32) + 1 - c0

        p = ty * chunk
        rel_r_f = rel_r.reshape(p, 1)
        rel_c_f = rel_c.reshape(p, 1)
        sy_f = sy.reshape(p, 1)
        sx_f = sx.reshape(p, 1)

        biota = jax.lax.broadcasted_iota(jnp.int32, (p, band), 1)
        wiota = jax.lax.broadcasted_iota(jnp.int32, (p, width), 1)
        rowsel = ((biota == rel_r_f).astype(jnp.float32) * (1.0 - sy_f)
                  + (biota == rel_r_f + 1).astype(jnp.float32) * sy_f)
        colsel = ((wiota == rel_c_f).astype(jnp.float32) * (1.0 - sx_f)
                  + (wiota == rel_c_f + 1).astype(jnp.float32) * sx_f)

        copy.wait()
        strip = strip_ref[...].astype(jnp.float32)
        # MXU: vertical interpolation for the whole tile at once.
        rowmix = jax.lax.dot_general(
            rowsel, strip, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (p, width)
        val = jnp.sum(rowmix * colsel, axis=1)             # VPU 2-tap blend

        # --- Part 3: inverse-square-law weighted accumulate -------------
        contrib = (val.reshape(ty, chunk) * (r * r)).astype(
            vol_in_ref.dtype)
        vol_out_ref[...] = vol_in_ref[...] + contrib[None]

    @pl.when(jnp.logical_not(active))
    def _():
        vol_out_ref[...] = vol_in_ref[...]


def backproject_kernel_micro(A_ref, img_ref, vol_in_ref, vol_out_ref,
                             strip_ref, sem,
                             *, o_mm, n_u, n_v, ty, chunk, band, width,
                             group, gband, gwidth):
    """Micro-window variant (hillclimb CT-5): strip DMA as usual, but the
    tap selection runs per ``group``-voxel micro-window instead of one
    tile-wide banded matmul.

    The plain kernel's rowsel matmul costs ``2 * band * width`` flops per
    voxel (16k at production size) because every voxel's one-hot row
    spans the whole strip.  Within a group of 8 consecutive voxels the
    taps span only ~``group * du`` columns and ~2 rows, so a
    ``(gband, gwidth)`` VMEM sub-slice + tiny selects bring it down to
    ``~2 * gband * gwidth`` (256) flops per voxel — the same napkin math
    as the jnp ``strip2`` strategy, now at kernel level where the strip
    load is a DMA rather than an XLA gather.
    """
    z = pl.program_id(0)
    y0 = (pl.program_id(1) * ty).astype(jnp.float32)
    x0 = (pl.program_id(2) * chunk).astype(jnp.float32)

    ix, iy, w, r, r0, c0, active = _tile_geometry(
        A_ref, o_mm, z, y0, x0, n_u=n_u, n_v=n_v, ty=ty, chunk=chunk,
        band=band, width=width, pad_rows=img_ref.shape[0],
        pad_cols=img_ref.shape[1])

    @pl.when(active)
    def _():
        copy = pltpu.make_async_copy(
            img_ref.at[pl.ds(r0, band), pl.ds(c0, width)], strip_ref,
            sem)
        copy.start()

        fx = jnp.floor(ix)
        fy = jnp.floor(iy)
        sx = (ix - fx).reshape(ty * chunk)
        sy = (iy - fy).reshape(ty * chunk)
        rel_r = (fy.astype(jnp.int32) + 1 - r0).reshape(ty * chunk)
        rel_c = (fx.astype(jnp.int32) + 1 - c0).reshape(ty * chunk)
        rw2 = (r * r).reshape(ty * chunk)

        copy.wait()
        n_groups = (ty * chunk) // group
        cols_per_row = chunk // group

        biota = jax.lax.broadcasted_iota(jnp.int32, (group, gband), 1)
        wiota = jax.lax.broadcasted_iota(jnp.int32, (group, gwidth), 1)

        def one_group(g, _):
            gs_ = g * group
            rr = jax.lax.dynamic_slice(rel_r, (gs_,), (group,))
            cc = jax.lax.dynamic_slice(rel_c, (gs_,), (group,))
            sxg = jax.lax.dynamic_slice(sx, (gs_,), (group,))
            syg = jax.lax.dynamic_slice(sy, (gs_,), (group,))
            wg = jax.lax.dynamic_slice(rw2, (gs_,), (group,))
            # Window origin from the *in-strip* tap positions only (far
            # out-of-detector voxels would otherwise drag the window off
            # the contributing taps; their own one-hots are zero either
            # way).
            r0g = jnp.clip(jnp.min(jnp.clip(rr, 0, band - 1)),
                           0, band - gband)
            c0g = jnp.clip(jnp.min(jnp.clip(cc, 0, width - 1)),
                           0, width - gwidth)
            win = strip_ref[pl.ds(r0g, gband), pl.ds(c0g, gwidth)]
            rowsel = ((biota == (rr - r0g)[:, None]).astype(jnp.float32)
                      * (1.0 - syg[:, None])
                      + (biota == (rr - r0g)[:, None] + 1).astype(
                          jnp.float32) * syg[:, None])
            colsel = ((wiota == (cc - c0g)[:, None]).astype(jnp.float32)
                      * (1.0 - sxg[:, None])
                      + (wiota == (cc - c0g)[:, None] + 1).astype(
                          jnp.float32) * sxg[:, None])
            mix = jax.lax.dot_general(
                rowsel, win.astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)      # (group, gwidth)
            val = jnp.sum(mix * colsel, axis=1) * wg
            row = gs_ // chunk
            col = (g % cols_per_row) * group
            cur = vol_in_ref[0, row, pl.ds(col, group)]
            vol_out_ref[0, row, pl.ds(col, group)] = \
                cur + val.astype(vol_in_ref.dtype)
            return 0

        jax.lax.fori_loop(0, n_groups, one_group, 0)

    @pl.when(jnp.logical_not(active))
    def _():
        vol_out_ref[...] = vol_in_ref[...]


def backproject_kernel_db(A_ref, img_ref, vol_in_ref, vol_out_ref,
                          strip_ref, sems,
                          *, o_mm, n_u, n_v, ty, chunk, band, width,
                          grid_dims):
    """Double-buffered variant: the strip DMA for grid step ``k+1`` is
    issued before step ``k``'s compute (hillclimb CT-3).

    KNC had no usable gather prefetch (the paper found
    ``vgatherpf0dps`` blocking and scalar prefetch too expensive,
    section 6.4); on TPU the strip origin is *computed* geometry, so the
    next tile's DMA can be launched exactly one step ahead into the
    other half of a (2, band, width) scratch — compute and DMA overlap
    with zero extra instructions on the critical path.
    """
    nz, ny, nc = grid_dims
    z = pl.program_id(0)
    yb = pl.program_id(1)
    cb = pl.program_id(2)
    step = (z * ny + yb) * nc + cb
    slot = jax.lax.rem(step, 2)

    pad_rows = img_ref.shape[0]
    pad_cols = img_ref.shape[1]

    def tile(zi, yi, ci):
        return _tile_geometry(
            A_ref, o_mm, zi, (yi * ty).astype(jnp.float32),
            (ci * chunk).astype(jnp.float32), n_u=n_u, n_v=n_v, ty=ty,
            chunk=chunk, band=band, width=width, pad_rows=pad_rows,
            pad_cols=pad_cols)

    def start_dma(r0, c0, s):
        pltpu.make_async_copy(
            img_ref.at[pl.ds(r0, band), pl.ds(c0, width)],
            strip_ref.at[s], sems.at[s]).start()

    ix, iy, w, r, r0, c0, active = tile(z, yb, cb)

    # First step primes its own slot.
    @pl.when(step == 0)
    def _():
        start_dma(r0, c0, slot)

    # Prefetch the next tile's strip into the other slot.
    nxt = step + 1
    last = nz * ny * nc - 1

    @pl.when(step < last)
    def _():
        cn = jax.lax.rem(nxt, nc)
        rest = jax.lax.div(nxt, nc)
        yn = jax.lax.rem(rest, ny)
        zn = jax.lax.div(rest, ny)
        _, _, _, _, r0n, c0n, _ = tile(zn, yn, cn)
        start_dma(r0n, c0n, 1 - slot)

    @pl.when(active)
    def _():
        pltpu.make_async_copy(
            img_ref.at[pl.ds(r0, band), pl.ds(c0, width)],
            strip_ref.at[slot], sems.at[slot]).wait()
        fx = jnp.floor(ix)
        fy = jnp.floor(iy)
        sx = ix - fx
        sy = iy - fy
        rel_r = fy.astype(jnp.int32) + 1 - r0
        rel_c = fx.astype(jnp.int32) + 1 - c0
        p = ty * chunk
        biota = jax.lax.broadcasted_iota(jnp.int32, (p, band), 1)
        wiota = jax.lax.broadcasted_iota(jnp.int32, (p, width), 1)
        rowsel = ((biota == rel_r.reshape(p, 1)).astype(jnp.float32)
                  * (1.0 - sy.reshape(p, 1))
                  + (biota == rel_r.reshape(p, 1) + 1).astype(jnp.float32)
                  * sy.reshape(p, 1))
        colsel = ((wiota == rel_c.reshape(p, 1)).astype(jnp.float32)
                  * (1.0 - sx.reshape(p, 1))
                  + (wiota == rel_c.reshape(p, 1) + 1).astype(jnp.float32)
                  * sx.reshape(p, 1))
        strip = strip_ref[slot].astype(jnp.float32)
        rowmix = jax.lax.dot_general(
            rowsel, strip, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        val = jnp.sum(rowmix * colsel, axis=1)
        contrib = (val.reshape(ty, chunk) * (r * r)).astype(
            vol_in_ref.dtype)
        vol_out_ref[...] = vol_in_ref[...] + contrib[None]

    @pl.when(jnp.logical_not(active))
    def _():
        # The prefetched strip for this inactive tile must still be
        # consumed so the semaphore balances.
        pltpu.make_async_copy(
            img_ref.at[pl.ds(r0, band), pl.ds(c0, width)],
            strip_ref.at[slot], sems.at[slot]).wait()
        vol_out_ref[...] = vol_in_ref[...]


def backproject_volume_pallas(volume, padded_img, A, *, o_mm, n_u, n_v,
                              ty=8, chunk=128, band=16, width=512,
                              double_buffer=False, micro=False,
                              micro_group=8, micro_band=4,
                              micro_width=32, interpret=False):
    """``pallas_call`` wrapper: one projection into the whole volume.

    ``volume``: (L, L, L) f32; ``padded_img``: zero-padded projection,
    row/col counts already rounded up by ops.py so ``band``/``width``
    slices always fit.  Returns the updated volume (input aliased).
    ``double_buffer=True`` selects the DMA-prefetching variant (CT-3);
    ``micro=True`` the per-group micro-window compute (CT-5).
    """
    L = volume.shape[0]
    assert L % ty == 0 and L % chunk == 0
    grid = (L, L // ty, L // chunk)

    vol_spec = pl.BlockSpec((1, ty, chunk), lambda z, y, x: (z, y, x))
    if micro:
        kernel = functools.partial(
            backproject_kernel_micro, o_mm=o_mm, n_u=n_u, n_v=n_v,
            ty=ty, chunk=chunk, band=band, width=width,
            group=micro_group, gband=micro_band, gwidth=micro_width)
        scratch = [pltpu.VMEM((band, width), padded_img.dtype),
                   pltpu.SemaphoreType.DMA]
        name = "backproject_strip_micro"
    elif double_buffer:
        kernel = functools.partial(
            backproject_kernel_db, o_mm=o_mm, n_u=n_u, n_v=n_v,
            ty=ty, chunk=chunk, band=band, width=width, grid_dims=grid)
        scratch = [pltpu.VMEM((2, band, width), padded_img.dtype),
                   pltpu.SemaphoreType.DMA((2,))]
        name = "backproject_strip_db"
    else:
        kernel = functools.partial(
            backproject_kernel, o_mm=o_mm, n_u=n_u, n_v=n_v,
            ty=ty, chunk=chunk, band=band, width=width)
        scratch = [pltpu.VMEM((band, width), padded_img.dtype),
                   pltpu.SemaphoreType.DMA]
        name = "backproject_strip"

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # A (3, 4)
            pl.BlockSpec(memory_space=pltpu.ANY),    # padded image (HBM)
            vol_spec,                                # volume tile in
        ],
        out_specs=vol_spec,
        out_shape=jax.ShapeDtypeStruct(volume.shape, volume.dtype),
        scratch_shapes=scratch,
        input_output_aliases={2: 0},
        interpret=interpret,
        name=name,
    )(A, padded_img, volume)
