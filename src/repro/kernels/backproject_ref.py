"""Pure-jnp oracle for the Pallas back projection kernel.

Independent of the kernel's blocking entirely: Listing-1 semantics
(per-tap bounds-checked bilinear, ``1/w^2`` weighting) vectorised over the
volume.  Any (shape, dtype, geometry) the kernel accepts must match this
to fp32 rounding — enforced by the sweep in
``tests/test_kernel_backproject.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backproject import (GeomStatic, accumulate, plane_coords,
                                    sample_scalar)

__all__ = ["backproject_volume_ref"]


def backproject_volume_ref(volume, image, A, gs: GeomStatic):
    """Reference volume update for one (unpadded) projection image."""
    A = jnp.asarray(A, jnp.float32)
    image = jnp.asarray(image)

    def plane(z, vol):
        ix, iy, w = plane_coords(A, gs, z)
        val = sample_scalar(image, ix, iy, gs)
        pl_ = jax.lax.dynamic_index_in_dim(vol, z, 0, keepdims=False)
        pl_ = accumulate(pl_, val, w)
        return jax.lax.dynamic_update_index_in_dim(vol, pl_, z, 0)

    return jax.lax.fori_loop(0, gs.L, plane, volume)
