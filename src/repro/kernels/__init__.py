"""Pallas TPU kernels (validated with interpret=True on CPU).

Each kernel ships the required triple: the ``pl.pallas_call`` kernel with
explicit BlockSpec/VMEM tiling, a jit'd ops wrapper, and a pure-jnp
oracle (``*_ref``).
"""

from .backproject_ops import (  # noqa: F401
    pallas_backproject_batch,
    pallas_backproject_one,
)
from .gather_kernel_ops import pallas_onehot_gather  # noqa: F401
from .slstm_ops import fused_slstm_forward  # noqa: F401
