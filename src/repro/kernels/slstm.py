"""Pallas TPU kernel: fused sLSTM recurrence (hillclimb LM-1).

The xlstm train/prefill cells are bound by the sLSTM token scan: XLA
keeps the (c, n, h, m) state and per-step gate tensors in HBM, so every
token pays ~10 state-array reads/writes — the roofline table shows the
memory term 500x above compute.  Unrolling cannot fix it (iteration 1,
refuted: XLA does not fuse across the sequential dependency).  This
kernel does what the XLA schedule cannot:

* state lives in VMEM scratch for the *entire sequence*;
* gate pre-activations stream HBM->VMEM in ``(TB, T_c, 4, TD)`` chunks,
  hidden states stream back per chunk;
* HBM traffic collapses to one read of ``zifo`` + one write of ``h``:
  ``5 * di * 4`` bytes/token instead of ~``40 * di``.

Feature dims are fully elementwise in the sLSTM cell, so the grid tiles
(batch x d_inner) are embarrassingly parallel.  Validated against
``repro.models.ssm.slstm_forward`` in ``tests/test_kernel_slstm.py``
(interpret mode; TPU is the target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["slstm_kernel", "slstm_pallas"]


def slstm_kernel(r_ref, zifo_ref, hs_ref, c_ref, n_ref, h_ref, m_ref,
                 zbuf, obuf, sem_in, sem_out, *, seq_chunk: int,
                 n_chunks: int, tb: int, td: int):
    """One grid step: the full sequence for a (TB, TD) feature tile."""
    b = pl.program_id(0)
    d = pl.program_id(1)

    c_ref[...] = jnp.zeros_like(c_ref)
    n_ref[...] = jnp.zeros_like(n_ref)
    h_ref[...] = jnp.zeros_like(h_ref)
    m_ref[...] = jnp.full_like(m_ref, -1e30)
    r = r_ref[...]                                        # (4, TD)

    def in_copy(ci):
        return pltpu.make_async_copy(
            zifo_ref.at[pl.ds(b * tb, tb),
                        pl.ds(ci * seq_chunk, seq_chunk),
                        slice(None), pl.ds(d * td, td)],
            zbuf, sem_in)

    def out_copy(ci):
        return pltpu.make_async_copy(
            obuf,
            hs_ref.at[pl.ds(b * tb, tb),
                      pl.ds(ci * seq_chunk, seq_chunk),
                      pl.ds(d * td, td)],
            sem_out)

    def chunk_body(ci, _):
        in_copy(ci).start()
        in_copy(ci).wait()

        def tok(t, _):
            z_in = zbuf[:, t, 0, :].astype(jnp.float32)   # (TB, TD)
            i_in = zbuf[:, t, 1, :].astype(jnp.float32)
            f_in = zbuf[:, t, 2, :].astype(jnp.float32)
            o_in = zbuf[:, t, 3, :].astype(jnp.float32)
            h = h_ref[...]
            zt = jnp.tanh(z_in + r[0] * h)
            ig = i_in + r[1] * h
            fg = f_in + r[2] * h
            og = jax.nn.sigmoid(o_in + r[3] * h)
            logf = -jax.nn.softplus(-fg)
            m = m_ref[...]
            m_new = jnp.maximum(logf + m, ig)
            dec = jnp.exp(logf + m - m_new)
            inc = jnp.exp(ig - m_new)
            c_new = c_ref[...] * dec + inc * zt
            n_new = n_ref[...] * dec + inc
            h_new = og * c_new / jnp.maximum(n_new, 1e-6)
            c_ref[...] = c_new
            n_ref[...] = n_new
            h_ref[...] = h_new
            m_ref[...] = m_new
            obuf[:, t, :] = h_new.astype(obuf.dtype)
            return 0

        jax.lax.fori_loop(0, seq_chunk, tok, 0)
        out_copy(ci).start()
        out_copy(ci).wait()
        return 0

    jax.lax.fori_loop(0, n_chunks, chunk_body, 0)


def slstm_pallas(zifo, r, *, tb: int = 8, td: int = 128,
                 seq_chunk: int = 256, interpret: bool = False):
    """Run the fused recurrence.

    ``zifo``: (B, S, 4, di) gate pre-activations; ``r``: (4, di) diag
    recurrence weights.  Returns hidden states (B, S, di) in
    ``zifo.dtype``.  B, di, S are padded by ops.py to tile multiples.
    """
    B, S, four, di = zifo.shape
    assert four == 4
    assert B % tb == 0 and di % td == 0 and S % seq_chunk == 0, \
        (B, S, di, tb, td, seq_chunk)
    grid = (B // tb, di // td)
    n_chunks = S // seq_chunk

    kernel = functools.partial(
        slstm_kernel, seq_chunk=seq_chunk, n_chunks=n_chunks, tb=tb,
        td=td)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, td), lambda b, d: (0, d)),   # r tile
            pl.BlockSpec(memory_space=pltpu.ANY),         # zifo (HBM)
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),   # hs (HBM)
        out_shape=jax.ShapeDtypeStruct((B, S, di), zifo.dtype),
        scratch_shapes=[
            pltpu.VMEM((tb, td), jnp.float32),            # c
            pltpu.VMEM((tb, td), jnp.float32),            # n
            pltpu.VMEM((tb, td), jnp.float32),            # h
            pltpu.VMEM((tb, td), jnp.float32),            # m
            pltpu.VMEM((tb, seq_chunk, 4, td), zifo.dtype),
            pltpu.VMEM((tb, seq_chunk, td), zifo.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
        name="slstm_fused",
    )(r, zifo)
