"""Jit'd wrapper for the one-hot gather kernel (padding + fallback)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gather import onehot_gather_pallas

__all__ = ["pallas_onehot_gather"]


@functools.partial(jax.jit,
                   static_argnames=("row_tile", "chunk", "interpret"))
def _run(table, ids, row_tile, chunk, interpret):
    V, D = table.shape
    n = ids.shape[0]
    pad_v = (-V) % chunk
    pad_n = (-n) % row_tile
    tbl = jnp.pad(table, ((0, pad_v), (0, 0))) if pad_v else table
    idv = jnp.pad(ids, (0, pad_n), constant_values=-1) if pad_n else ids
    out = onehot_gather_pallas(tbl, idv, row_tile=row_tile, chunk=chunk,
                               interpret=interpret)
    return out[:n]


def pallas_onehot_gather(table, ids, *, row_tile: int = 256,
                         chunk: int = 512,
                         interpret: bool | None = None):
    """``table[ids]`` via the MXU; auto-interprets off TPU.

    Accepts any leading ids shape; out-of-range ids give zero rows.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = ids.shape
    flat = ids.reshape(-1).astype(jnp.int32)
    row_tile = min(row_tile, max(8, flat.shape[0]))
    out = _run(jnp.asarray(table), flat, row_tile, chunk, interpret)
    return out.reshape(shape + (table.shape[-1],))
