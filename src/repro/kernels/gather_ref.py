"""Pure-jnp oracle for the one-hot gather kernel.

``table[ids]`` with out-of-range ids mapped to zero rows — the exact
semantics ``onehot_gather_pallas`` implements.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gather_ref"]


def gather_ref(table, ids):
    V = table.shape[0]
    ok = (ids >= 0) & (ids < V)
    rows = jnp.take(table, jnp.clip(ids, 0, V - 1), axis=0)
    return jnp.where(ok[..., None], rows, 0)
