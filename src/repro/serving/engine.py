"""Batched serving engine: slot-based continuous batching.

A production-shaped (if single-host) serving loop over the model zoo's
``prefill``/``decode_step``:

* fixed ``n_slots`` concurrent sequences share one decode cache (the
  ``decode_32k`` dry-run cell is exactly one such fused step at B=128);
* arriving requests are prefilled into a free slot (prompt lengths are
  right-aligned into the shared cache with per-slot offsets);
* one jitted ``decode_step`` advances *all* active slots per tick —
  finished slots (EOS or max_tokens) are freed and immediately refilled
  (continuous batching);
* greedy or temperature sampling.

The engine is deliberately cache-layout-compatible with the dry-run's
``serve_step`` so the roofline numbers describe this exact loop.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import decode_step, init_cache, prefill

__all__ = ["Request", "ServingEngine"]


def _masked_decode_step(params, cache, tokens, index, slot_mask, *, cfg):
    """One decode step whose cache writes land only on masked-in slots.

    The engine advances slots in groups of equal position index, but
    ``decode_step`` always runs the full batch: without masking, every
    group call would also rewrite the cache rows of slots *outside* the
    group at that group's index — the wrong position.  Merging through
    ``slot_mask`` keeps out-of-group rows bit-identical to their
    pre-step state.

    The merge touches every cache leaf in full; masking just the written
    slice is not possible uniformly because recurrent-state leaves
    (mamba/slstm) have no time axis — their whole row changes per step.
    The cost is k(distinct positions) full-cache passes per tick;
    removing the group loop entirely needs per-slot index support in
    attention_decode (see the NOTE in ``step``).
    """
    logits, new_cache = decode_step(params, cfg, cache, tokens, index)

    def merge(old, new):
        m = slot_mask.reshape((1, slot_mask.shape[0])
                              + (1,) * (new.ndim - 2))
        return jnp.where(m, new, old)

    return logits, jax.tree.map(merge, cache, new_cache)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg, params, *, n_slots: int = 8,
                 max_len: int = 512, eos_id: int | None = None,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.cache = init_cache(cfg, n_slots, max_len)
        self.index = np.zeros(n_slots, np.int32)      # per-slot position
        self.slot_req: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self._step = jax.jit(partial(_masked_decode_step, cfg=self.cfg))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Prefill queued requests into free slots (one at a time).

        The first token after prefill is drawn through ``_sample`` (it
        used to be unconditional argmax, ignoring ``temperature``), and
        ``max_tokens``/EOS are honoured immediately — a ``max_tokens=1``
        request retires here without ever occupying a decode slot.
        """
        for slot in self._free_slots():
            while self.queue:
                req = self.queue.pop(0)
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                # Single-sequence prefill, then splice its cache into the
                # shared-slot cache at batch row `slot`.
                logits, cache1 = prefill(self.params, self.cfg,
                                         {"tokens": toks},
                                         max_len=self.max_len)
                self.cache = jax.tree.map(
                    lambda full, one: full.at[:, slot].set(one[:, 0]),
                    self.cache, cache1)
                self.index[slot] = len(req.prompt)
                tok = int(np.asarray(self._sample(
                    logits[:, -1].astype(jnp.float32),
                    jnp.asarray([req.temperature], jnp.float32)))[0])
                req.out_tokens.append(tok)
                if (self.eos_id is not None and tok == self.eos_id) \
                        or len(req.out_tokens) >= req.max_tokens:
                    req.done = True
                    continue        # slot still free: admit the next one
                self.slot_req[slot] = req
                break

    # ------------------------------------------------------------------
    def _sample(self, logits, temps):
        greedy = jnp.argmax(logits, axis=-1)
        self.key, k = jax.random.split(self.key)
        sampled = jax.random.categorical(
            k, logits / jnp.maximum(temps[:, None], 1e-6))
        return jnp.where(temps > 0, sampled, greedy)

    def step(self):
        """One engine tick: admit, decode every active slot, retire."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        last = np.zeros((self.n_slots, 1), np.int32)
        temps = np.zeros((self.n_slots,), np.float32)
        for i in active:
            req = self.slot_req[i]
            last[i, 0] = req.out_tokens[-1]
            temps[i] = req.temperature
        # NOTE: slots share one position index per decode call; we step
        # at the max index and rely on per-slot causal masks via cache
        # zero-fill.  Slot-accurate positions need per-slot index support
        # in attention_decode; we conservatively use each slot's own
        # index by looping groups with equal index.  Each group call runs
        # the full batch, so the cache update is masked to the group —
        # otherwise every call would rewrite the other slots' rows at
        # this group's (wrong) position.
        by_index: dict[int, list[int]] = {}
        for i in active:
            by_index.setdefault(int(self.index[i]), []).append(i)
        for idx in sorted(by_index):
            slot_mask = np.zeros((self.n_slots,), bool)
            slot_mask[by_index[idx]] = True
            logits, self.cache = self._step(
                params=self.params, cache=self.cache,
                tokens=jnp.asarray(last), index=jnp.int32(idx),
                slot_mask=jnp.asarray(slot_mask))
            toks = np.asarray(self._sample(
                logits[:, -1].astype(jnp.float32), jnp.asarray(temps)))
            for i in by_index[idx]:
                req = self.slot_req[i]
                tok = int(toks[i])
                req.out_tokens.append(tok)
                self.index[i] += 1
                if (self.eos_id is not None and tok == self.eos_id) \
                        or len(req.out_tokens) >= req.max_tokens \
                        or self.index[i] >= self.max_len - 1:
                    req.done = True
                    self.slot_req[i] = None
        return True

    def run_until_done(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks
