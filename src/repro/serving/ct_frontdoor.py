"""Multi-tenant CT front door: async admission over the streaming engine.

The LM :class:`repro.serving.engine.ServingEngine` proves the shape —
continuous batching over a fixed slot pool — and the streaming
:class:`repro.streaming.ReconstructionEngine` is its CT analogue.  What
neither has is a *front*: a place where many concurrent clients hand in
interleaved scan streams, where admission order is a policy rather than
an accident of arrival, where a full house answers "retry in t seconds"
instead of buffering without bound, and where a client can walk away
mid-scan without leaking a slot.  This module is that tier
(DESIGN.md §14):

* **One payload.** Every arrival is a
  :class:`repro.streaming.ProjectionChunk` — the same typed currency the
  engine's ``submit`` takes.
* **Pluggable admission.** The engine's own queue stays empty; the front
  door holds all waiting scans and, whenever the backend has a free
  slot, asks its :class:`AdmissionPolicy` which one goes next — FIFO,
  shortest-remaining-scan-first with aging (:class:`SRSFPolicy`),
  SLO-deadline least-slack (:class:`DeadlinePolicy`), or per-tenant fair
  share (:class:`FairSharePolicy`).
* **Backpressure, not buffering.** The pending queue is bounded
  (``max_pending``); when it is full and no slot is free,
  :meth:`CTFrontDoor.open_scan` raises :class:`Backpressure` carrying a
  ``retry_after`` hint derived from the measured scan service time.
  Chunks for an admitted-or-pending scan are bounded by that scan's
  *declared* ``n_proj`` — nothing in the tier grows without a declared
  limit.
* **Cancellation.** :meth:`CTFrontDoor.cancel` drops a pending ticket or
  aborts an in-flight one (``ReconstructionEngine.abort_scan`` retires
  the slot, zeroes it, and refills), so abort-then-reuse of a slot is
  bit-clean.
* **Sharded mode.** With a ``mesh``, completed scans run
  :func:`repro.core.pipeline.sharded_reconstruct(prefiltered=False)`,
  which drives ``reconstruct_shards(..., z0=rank_slab)`` per rank — one
  scan's volume spans the ``data`` mesh axis while the front door still
  does admission, backpressure, and cancellation.

Concurrency model: single event loop, cooperative.  Device work is
dispatched inline (JAX's async dispatch overlaps it with host code);
``await`` points let client coroutines interleave their streams.  The
front door itself is not thread-safe — one loop owns it.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time

import numpy as np

from repro.core.geometry import Geometry
from repro.streaming import ProjectionChunk, ReconstructionEngine

__all__ = [
    "AdmissionPolicy",
    "FIFOPolicy",
    "SRSFPolicy",
    "DeadlinePolicy",
    "FairSharePolicy",
    "POLICIES",
    "PolicyContext",
    "Backpressure",
    "ScanAborted",
    "ScanTicket",
    "CTFrontDoor",
]


class Backpressure(RuntimeError):
    """The front door is full: no free slot and the pending queue is at
    ``max_pending``.  ``retry_after`` (seconds) is the service-time-based
    hint a well-behaved client sleeps before retrying."""

    def __init__(self, retry_after: float):
        self.retry_after = float(retry_after)
        super().__init__(
            f"serving tier full; retry after {self.retry_after:.3f}s")


class ScanAborted(RuntimeError):
    """Awaited result of a scan that was cancelled."""


@dataclasses.dataclass
class ScanTicket:
    """One client scan as the front door tracks it.

    ``deadline`` is an absolute clock value (same clock as the front
    door's, default ``time.monotonic``) — the SLO instant the finished
    volume is due, which :class:`DeadlinePolicy` schedules against.
    """

    tid: int
    tenant: str
    n_proj: int
    deadline: float | None = None
    arrived: float = 0.0              # clock time open_scan admitted it
    admitted_at: float | None = None  # clock time it got a slot
    first_submit: float | None = None
    finished_at: float | None = None
    state: str = "pending"            # pending | active | done | aborted
    sid: int | None = None            # backend scan id once active
    received: int = 0
    buffered: list = dataclasses.field(default_factory=list)
    volume: object | None = None
    _event: asyncio.Event = dataclasses.field(default_factory=asyncio.Event)

    @property
    def remaining(self) -> int:
        """Projections still to fold end-to-end.  A queued scan has its
        whole declared length ahead of it whatever has been buffered, so
        for pending tickets this is ``n_proj`` — SRSF over a queue is
        shortest-declared-scan-first (plus aging)."""
        return self.n_proj

    @property
    def settled(self) -> bool:
        return self.state in ("done", "aborted")


@dataclasses.dataclass(frozen=True)
class PolicyContext:
    """What a policy may look at when choosing the next admission.

    ``active``/``admitted`` map tenant -> in-flight count / total
    admissions; ``est_proj_s`` is the front door's EWMA of measured
    seconds per projection (0.0 until the first scan completes).
    """

    now: float
    active: dict
    admitted: dict
    est_proj_s: float = 0.0


class AdmissionPolicy:
    """Chooses which pending ticket takes the next free slot.

    ``select`` gets the pending tickets *in arrival order* and a
    :class:`PolicyContext`; it returns the index of the winner.  Stable
    ties (Python ``min`` keeps the first minimum) make every policy
    FIFO among equals.
    """

    name = "abstract"

    def select(self, pending, ctx: PolicyContext) -> int:
        raise NotImplementedError


class FIFOPolicy(AdmissionPolicy):
    """Arrival order — the engine's own queue discipline, lifted."""

    name = "fifo"

    def select(self, pending, ctx: PolicyContext) -> int:
        return 0


class SRSFPolicy(AdmissionPolicy):
    """Shortest-remaining-scan-first with linear aging.

    Key: ``remaining - aging * wait_seconds``.  Pure SRSF (``aging=0``)
    starves a long scan under a steady stream of short ones; with
    ``aging > 0`` (projections of credit per waiting second) a scan that
    has waited ``(its remaining - shortest remaining) / aging`` seconds
    outranks every fresh short arrival — the starvation bound
    ``tests/test_frontdoor.py`` holds as a property.
    """

    name = "srsf"

    def __init__(self, aging: float = 1.0):
        if aging < 0:
            raise ValueError(f"aging must be >= 0, got {aging}")
        self.aging = float(aging)

    def select(self, pending, ctx: PolicyContext) -> int:
        def key(i):
            t = pending[i]
            return t.remaining - self.aging * (ctx.now - t.arrived)

        return min(range(len(pending)), key=key)


class DeadlinePolicy(AdmissionPolicy):
    """SLO deadlines: least slack first.

    Slack = ``deadline - now - remaining * est_proj_s`` — time to spare
    if the scan started this instant at the measured per-projection
    rate.  Tickets without a deadline have infinite slack and are served
    FIFO after every deadlined one.
    """

    name = "deadline"

    def select(self, pending, ctx: PolicyContext) -> int:
        def slack(i):
            t = pending[i]
            if t.deadline is None:
                return float("inf")
            return t.deadline - ctx.now - t.remaining * ctx.est_proj_s

        return min(range(len(pending)), key=slack)


class FairSharePolicy(AdmissionPolicy):
    """Per-tenant fair share: least in-flight, then least ever-admitted.

    A tenant flooding the queue only competes with itself — each free
    slot goes to the tenant with the fewest scans in service (total
    admissions break ties, arrival order after that).
    """

    name = "fair"

    def select(self, pending, ctx: PolicyContext) -> int:
        def key(i):
            t = pending[i]
            return (ctx.active.get(t.tenant, 0),
                    ctx.admitted.get(t.tenant, 0))

        return min(range(len(pending)), key=key)


POLICIES = {"fifo": FIFOPolicy, "srsf": SRSFPolicy,
            "deadline": DeadlinePolicy, "fair": FairSharePolicy}


def _resolve_policy(policy) -> AdmissionPolicy:
    if isinstance(policy, AdmissionPolicy):
        return policy
    if isinstance(policy, str):
        try:
            return POLICIES[policy.lower()]()
        except KeyError:
            raise ValueError(
                f"unknown admission policy {policy!r}; want one of "
                f"{tuple(POLICIES)} or an AdmissionPolicy instance"
            ) from None
    raise TypeError(f"policy must be a name or AdmissionPolicy, "
                    f"got {type(policy).__name__}")


# ----------------------------------------------------------------------
# Backends: where admitted scans actually reconstruct
# ----------------------------------------------------------------------
class _EngineBackend:
    """Single-process slot machine: the streaming ReconstructionEngine."""

    def __init__(self, engine: ReconstructionEngine):
        self.engine = engine

    @property
    def n_slots(self) -> int:
        return self.engine.n_slots

    @property
    def free_slots(self) -> int:
        return self.engine.free_slots

    def validate_declared(self, n_proj: int) -> None:
        pass                        # any positive length streams fine

    def begin(self, n_proj: int) -> int:
        return self.engine.begin_scan(n_proj=n_proj)

    def submit(self, sid: int, chunk: ProjectionChunk) -> None:
        self.engine.submit(sid, chunk)

    def pump(self) -> None:
        self.engine.drain()

    def poll(self, sid: int):
        scan = self.engine.scans.get(sid)
        if scan is not None and scan.done:
            return self.engine.result(sid, pop=True)
        return None

    def abort(self, sid: int) -> None:
        self.engine.abort_scan(sid)


class _ShardedBackend:
    """Mesh path: one scan's volume spans the ``data`` axis.

    Chunks stage host-side by *global angle index*; when the full scan
    is in, :func:`repro.core.pipeline.sharded_reconstruct` runs with
    ``prefiltered=False`` — each rank FDK-filters its projection subset
    in-shard and ``reconstruct_shards(..., z0=rank_slab)`` back-projects
    its z-slab, so filtering scales with the ``proj`` axes and the
    volume with ``data``.  The in-shard filter needs the whole scan
    (Parker rows by global angle index), so sharded scans must declare
    ``n_proj == geom.n_proj`` and each angle may arrive exactly once.

    ``n_slots`` here bounds how many scans may stage concurrently — the
    same admission currency as the engine backend, with host staging
    memory (``n_proj * n_v * n_u * 4`` bytes per scan) as the resource.
    """

    def __init__(self, geom: Geometry, mesh, *, n_slots: int = 2,
                 volume_axis: str = "data",
                 proj_axes: tuple[str, ...] = ("model",),
                 strategy: str = "strip2", pbatch: int | None = None,
                 short_scan: bool | None = None, **opts):
        self.geom = geom
        self.mesh = mesh
        self.n_slots = int(n_slots)
        self._recon_kw = dict(strategy=strategy, volume_axis=volume_axis,
                              proj_axes=tuple(proj_axes), pbatch=pbatch,
                              prefiltered=False, short_scan=short_scan,
                              **opts)
        self._staged: dict[int, dict] = {}
        self._next_sid = 0

    @property
    def free_slots(self) -> int:
        return max(0, self.n_slots - len(self._staged))

    def validate_declared(self, n_proj: int) -> None:
        if n_proj != self.geom.n_proj:
            raise ValueError(
                f"sharded mode filters in-shard by global angle index, so "
                f"scans must be full: declared n_proj={n_proj}, geometry "
                f"has {self.geom.n_proj}")

    def begin(self, n_proj: int) -> int:
        self.validate_declared(n_proj)
        sid = self._next_sid
        self._next_sid += 1
        g = self.geom
        self._staged[sid] = {
            "projs": np.zeros((g.n_proj, g.n_v, g.n_u), np.float32),
            "mats": np.zeros((g.n_proj, 3, 4), np.float32),
            "seen": np.zeros((g.n_proj,), bool),
        }
        return sid

    def submit(self, sid: int, chunk: ProjectionChunk) -> None:
        st = self._staged[sid]
        projs, mats, idx = chunk.arrays()
        if idx.min() < 0 or idx.max() >= self.geom.n_proj:
            raise ValueError(
                f"angle indices must lie in [0, {self.geom.n_proj})")
        if st["seen"][idx].any() or len(set(idx.tolist())) != len(idx):
            raise ValueError(
                "sharded mode takes each angle index exactly once; "
                f"duplicate in {idx.tolist()}")
        st["projs"][idx] = np.asarray(projs, np.float32)
        st["mats"][idx] = np.asarray(mats, np.float32)
        st["seen"][idx] = True

    def pump(self) -> None:
        pass                        # nothing incremental to advance

    def poll(self, sid: int):
        from repro.core.pipeline import sharded_reconstruct

        st = self._staged.get(sid)
        if st is None or not st["seen"].all():
            return None
        del self._staged[sid]
        return sharded_reconstruct(st["projs"], st["mats"], self.geom,
                                   self.mesh, **self._recon_kw)

    def abort(self, sid: int) -> None:
        self._staged.pop(sid, None)


# ----------------------------------------------------------------------
# The front door
# ----------------------------------------------------------------------
class CTFrontDoor:
    """Async multi-tenant admission over a reconstruction backend.

    >>> fd = CTFrontDoor(geom, n_slots=2, max_pending=8, policy="srsf")
    >>> ticket = await fd.open_scan(tenant="clinic-a")
    >>> await fd.submit(ticket, ProjectionChunk(projs, mats, idx))
    >>> volume = await fd.result(ticket)

    ``open_scan`` raises :class:`Backpressure` (with ``retry_after``)
    when no slot is free and ``max_pending`` tickets already wait —
    bounded queues all the way down.  ``mesh=...`` selects the sharded
    backend; otherwise a :class:`ReconstructionEngine` is built from
    ``engine_opts`` (or pass a prebuilt one as ``engine=``).
    """

    def __init__(self, geom: Geometry, *, n_slots: int = 4,
                 max_pending: int = 16, policy="fifo", engine=None,
                 mesh=None, retry_after: float | None = None,
                 clock=time.monotonic, **engine_opts):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.geom = geom
        self.policy = _resolve_policy(policy)
        self.max_pending = int(max_pending)
        self._clock = clock
        if mesh is not None:
            if engine is not None:
                raise ValueError("pass engine= or mesh=, not both")
            self._backend = _ShardedBackend(geom, mesh, n_slots=n_slots,
                                            **engine_opts)
        else:
            if engine is None:
                engine = ReconstructionEngine(geom, n_slots=n_slots,
                                              **engine_opts)
            self._backend = _EngineBackend(engine)
        self._pending: list[ScanTicket] = []      # arrival order
        self._active: dict[int, ScanTicket] = {}
        self._next_tid = 0
        self._active_by_tenant: dict[str, int] = {}
        self._admitted_by_tenant: dict[str, int] = {}
        self._retry_after = retry_after
        self._ewma_scan_s: float | None = None    # per-scan service time
        self._ewma_proj_s: float | None = None    # per-projection
        self.stats = {"opened": 0, "rejected": 0, "admitted": 0,
                      "completed": 0, "cancelled": 0}

    # ------------------------------------------------------------------
    # Client surface (async)
    # ------------------------------------------------------------------
    async def open_scan(self, *, tenant: str = "default",
                        n_proj: int | None = None,
                        deadline: float | None = None) -> ScanTicket:
        """Admit a scan into the tier, or raise :class:`Backpressure`.

        ``deadline`` is an absolute value of the front door's clock (SLO
        instant the volume is due) — only :class:`DeadlinePolicy` reads
        it.  The returned ticket is ``pending`` until a slot frees and
        the policy picks it.
        """
        self.pump()
        n = int(n_proj) if n_proj is not None else self.geom.n_proj
        if n <= 0:
            raise ValueError(f"n_proj must be positive, got {n_proj!r}")
        # A declared length the backend can never serve must fail the
        # *opening* client here — not surface mid-pump out of whichever
        # call happens to admit it later.
        self._backend.validate_declared(n)
        if self._backend.free_slots <= 0 \
                and len(self._pending) >= self.max_pending:
            self.stats["rejected"] += 1
            raise Backpressure(self._retry_hint())
        ticket = ScanTicket(tid=self._next_tid, tenant=str(tenant),
                            n_proj=n, deadline=deadline,
                            arrived=self._clock())
        self._next_tid += 1
        self._pending.append(ticket)
        self.stats["opened"] += 1
        self.pump()
        await asyncio.sleep(0)
        return ticket

    async def submit(self, ticket: ScanTicket,
                     chunk: ProjectionChunk) -> None:
        """Hand in one chunk of ``ticket``'s stream.

        Active scans feed the backend directly; pending scans buffer —
        bounded by the scan's declared ``n_proj``, which over-submission
        breaches loudly here.
        """
        if not isinstance(chunk, ProjectionChunk):
            raise TypeError(
                f"submit takes a ProjectionChunk, got "
                f"{type(chunk).__name__}")
        if ticket.settled:
            raise ValueError(
                f"scan {ticket.tid} already {ticket.state}")
        k = chunk.n
        if ticket.received + k > ticket.n_proj:
            raise ValueError(
                f"scan {ticket.tid} declared {ticket.n_proj} projections; "
                f"{ticket.received + k} submitted")
        if ticket.first_submit is None:
            ticket.first_submit = self._clock()
        ticket.received += k
        if ticket.state == "active":
            self._backend.submit(ticket.sid, chunk)
        else:
            ticket.buffered.append(chunk)
        self.pump()
        await asyncio.sleep(0)

    async def result(self, ticket: ScanTicket, timeout: float | None = None):
        """Await the finished volume (raises :class:`ScanAborted` for a
        cancelled ticket, ``asyncio.TimeoutError`` past ``timeout``)."""
        self.pump()
        if not ticket.settled:
            if timeout is None:
                await ticket._event.wait()
            else:
                await asyncio.wait_for(ticket._event.wait(), timeout)
        if ticket.state == "aborted":
            raise ScanAborted(f"scan {ticket.tid} was cancelled")
        return ticket.volume

    async def cancel(self, ticket: ScanTicket) -> bool:
        """Drop a scan: dequeue a pending one, abort an active one.

        Returns True when the scan was live and is now aborted; a scan
        that already finished keeps its result and returns False.
        """
        if ticket.settled:
            return False
        if ticket.state == "pending":
            self._pending.remove(ticket)
        else:                                       # active
            self._backend.abort(ticket.sid)
            del self._active[ticket.tid]
            self._active_by_tenant[ticket.tenant] -= 1
        ticket.state = "aborted"
        ticket.buffered.clear()
        ticket.finished_at = self._clock()
        self.stats["cancelled"] += 1
        ticket._event.set()
        self.pump()
        await asyncio.sleep(0)
        return True

    # ------------------------------------------------------------------
    # Scheduler core (sync — one event loop owns the front door)
    # ------------------------------------------------------------------
    def pump(self) -> None:
        """Admit while slots are free, advance the backend, retire
        finished scans.  Loops until a fixed point so a retirement's
        freed slot admits in the same call."""
        while True:
            admitted = self._admit_ready()
            self._backend.pump()
            completed = self._reap_completions()
            if not admitted and not completed:
                return

    def _admit_ready(self) -> bool:
        any_admitted = False
        while self._pending and self._backend.free_slots > 0:
            now = self._clock()
            ctx = PolicyContext(now=now,
                                active=dict(self._active_by_tenant),
                                admitted=dict(self._admitted_by_tenant),
                                est_proj_s=self._ewma_proj_s or 0.0)
            i = int(self.policy.select(tuple(self._pending), ctx))
            if not 0 <= i < len(self._pending):
                raise IndexError(
                    f"policy {self.policy.name!r} selected index {i} "
                    f"outside the pending queue (len "
                    f"{len(self._pending)})")
            ticket = self._pending.pop(i)
            ticket.sid = self._backend.begin(ticket.n_proj)
            ticket.state = "active"
            ticket.admitted_at = now
            self._active[ticket.tid] = ticket
            self._active_by_tenant[ticket.tenant] = \
                self._active_by_tenant.get(ticket.tenant, 0) + 1
            self._admitted_by_tenant[ticket.tenant] = \
                self._admitted_by_tenant.get(ticket.tenant, 0) + 1
            self.stats["admitted"] += 1
            for chunk in ticket.buffered:
                self._backend.submit(ticket.sid, chunk)
            ticket.buffered.clear()
            any_admitted = True
        return any_admitted

    def _reap_completions(self) -> bool:
        any_done = False
        for ticket in list(self._active.values()):
            vol = self._backend.poll(ticket.sid)
            if vol is None:
                continue
            ticket.volume = vol
            ticket.state = "done"
            ticket.finished_at = self._clock()
            del self._active[ticket.tid]
            self._active_by_tenant[ticket.tenant] -= 1
            self.stats["completed"] += 1
            service = ticket.finished_at - ticket.admitted_at
            self._ewma_scan_s = (service if self._ewma_scan_s is None
                                 else 0.7 * self._ewma_scan_s
                                 + 0.3 * service)
            per = service / max(1, ticket.n_proj)
            self._ewma_proj_s = (per if self._ewma_proj_s is None
                                 else 0.7 * self._ewma_proj_s + 0.3 * per)
            ticket._event.set()
            any_done = True
        return any_done

    def _retry_hint(self) -> float:
        if self._retry_after is not None:
            return self._retry_after
        # One slot frees roughly every (scan service time / n_slots);
        # before any completion has been measured, hint 100 ms.
        per_scan = self._ewma_scan_s if self._ewma_scan_s else 0.1
        return max(0.01, per_scan / max(1, self._backend.n_slots))

    # ------------------------------------------------------------------
    @property
    def pending(self) -> int:
        return len(self._pending)

    @property
    def active(self) -> int:
        return len(self._active)

    @property
    def free_slots(self) -> int:
        return self._backend.free_slots
