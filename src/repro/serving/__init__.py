"""Serving: batched prefill/decode LM engine + the CT front door."""

from .ct_frontdoor import (AdmissionPolicy, Backpressure,  # noqa: F401
                           CTFrontDoor, DeadlinePolicy, FairSharePolicy,
                           FIFOPolicy, POLICIES, PolicyContext,
                           ScanAborted, ScanTicket, SRSFPolicy)
from .engine import Request, ServingEngine  # noqa: F401

__all__ = [
    "AdmissionPolicy", "Backpressure", "CTFrontDoor", "DeadlinePolicy",
    "FairSharePolicy", "FIFOPolicy", "POLICIES", "PolicyContext",
    "ScanAborted", "ScanTicket", "SRSFPolicy",
    "Request", "ServingEngine",
]
