"""Serving: batched prefill/decode engine with slot scheduling."""

from .engine import Request, ServingEngine  # noqa: F401
