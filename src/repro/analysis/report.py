"""Render the §Dry-run / §Roofline tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.analysis.report experiments/dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys


def load(dirname: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x):
    if x == 0:
        return "0"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6),
                        ("ns", 1e-9)):
        if x >= scale:
            return f"{x / scale:.2f}{unit}"
    return f"{x:.1e}s"


def roofline_table(recs, mesh: str) -> str:
    rows = ["| arch | shape | step | compute | memory | collective | "
            "dominant | MFU-bound | useful/HLO | live GB | fits 16GB |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"skip | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | "
                        f"| | | | |")
            continue
        ro = r["roofline"]
        # MFU bound: fraction of peak if the dominant term were the
        # only cost (compute_s / bound_s).
        mfu = ro["compute_s"] / ro["bound_s"] if ro["bound_s"] else 0.0
        ur = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r.get('step', '')} "
            f"| {_fmt_s(ro['compute_s'])} | {_fmt_s(ro['memory_s'])} "
            f"| {_fmt_s(ro['collective_s'])} | {ro['dominant']} "
            f"| {mfu:.1%} | {ur:.2f} "
            f"| {r['memory']['live_bytes'] / 1e9:.1f} "
            f"| {'yes' if r.get('fits_16gb_hbm') else 'NO'} |")
    return "\n".join(rows)


def summary(recs):
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    lines = [f"cells: {len(ok)} ok, {len(skip)} skipped, "
             f"{len(err)} error"]
    for r in err:
        lines.append(f"  ERROR {r['arch']} {r['shape']} {r['mesh']}: "
                     f"{r.get('error', '?')[:120]}")
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    print(summary(recs))
    for mesh in ("pod", "multipod"):
        if any(r["mesh"] == mesh for r in recs):
            print(f"\n### Roofline — mesh `{mesh}` "
                  f"({'256' if mesh == 'pod' else '512'} chips)\n")
            print(roofline_table(recs, mesh))


if __name__ == "__main__":
    main()
