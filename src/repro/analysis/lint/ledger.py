"""DMA-ledger replay: prove every kernel's issue/wait discipline.

Each Pallas variant in :mod:`repro.kernels.backproject` hand-rolls its
strip DMA pipeline — the 2-slot rotation in ``_batch_strip_loop``, the
``depth``-slot ``start_dma``/``wait_strip`` rotations of the ``_db``
kernels, the one-slab copy of the shared-window kernel.  Mosaic checks
none of the invariants these rely on; an unbalanced semaphore or a
slot overwritten while its copy is in flight is silent data corruption
on hardware (and often *passes* in interpret mode, which serialises the
copies).

This pass replays the *actual kernel functions* — not a model of them —
by swapping the module's ``pl``/``pltpu``/``jax`` globals for recording
stubs and running every grid step eagerly:

* refs are numpy-backed (:class:`StubRef`), so indexing/arithmetic run
  for real and out-of-bounds slicing fails loudly;
* ``pltpu.make_async_copy(...).start()/.wait()`` post to a
  :class:`Ledger` keyed by semaphore, with the copy's full
  (source-view, dest-view) descriptor, so producer/consumer *origin
  agreement* is checked, not just counts;
* ``pl.when`` executes its branch iff the (concrete) predicate holds
  and ``jax.lax.fori_loop`` becomes a Python loop, so every issue/wait
  the kernel would perform is observed exactly once per grid step.

Invariants proved per replay (each violation is a finding):

* **balance** — every started copy is awaited exactly once
  (``unwaited-dma``), and no wait fires on an idle semaphore
  (``wait-before-issue``);
* **origin agreement** — a wait's recomputed descriptor matches what
  the issuer posted (``wait-descriptor-mismatch``);
* **slot liveness** — no copy targets a slot whose previous copy is
  still in flight (``slot-overwrite``);
* **depth bounds** — peak in-flight copies stay within the scratch's
  slot count (``in-flight-exceeds-slots``) and reach the depth the
  variant promises (``pipeline-under-depth``): a rotation that never
  fills is a silently-degraded pipeline, PR 5's bug class.

The replay space crosses all seven variants with ``db_depth`` ∈
{2, 3, 4}, ``pbatch`` ∈ {4, 3} (3 exercises the ``pbatch ∤ n_proj``
remainder group the batch wrapper dispatches at the tail), and the
quantized (int8 + scale-sideband) ref layout.
"""

from __future__ import annotations

import contextlib
import dataclasses
import importlib.util
import itertools

import numpy as np

import jax
import jax.numpy as jnp

from .common import Finding, PassResult

__all__ = ["StubRef", "Ledger", "ReplayCase", "builtin_cases", "replay",
           "replay_fixture", "run_ledger_pass"]


# ----------------------------------------------------------------------
# Recording stubs for pl / pltpu / jax.lax
# ----------------------------------------------------------------------

class _DS:
    """Concrete stand-in for ``pl.ds``: a (start, size) slice."""

    __slots__ = ("start", "size")

    def __init__(self, start, size):
        self.start = int(start)
        self.size = int(size)

    def as_slice(self):
        return slice(self.start, self.start + self.size)

    def key(self):
        return ("ds", self.start, self.size)


def _norm(idx):
    """Hashable descriptor form of an index tuple."""
    if not isinstance(idx, tuple):
        idx = (idx,)
    out = []
    for i in idx:
        if isinstance(i, _DS):
            out.append(i.key())
        elif i is Ellipsis:
            out.append("...")
        else:
            out.append(int(i))
    return tuple(out)


def _np_index(idx):
    """Numpy indexing form of an index tuple."""
    if not isinstance(idx, tuple):
        idx = (idx,)
    out = []
    for i in idx:
        if isinstance(i, _DS):
            out.append(i.as_slice())
        elif i is Ellipsis:
            out.append(Ellipsis)
        else:
            out.append(int(i))
    return tuple(out)


class _View:
    """A ``ref.at[idx]`` view: descriptor for the ledger, data for the
    copy."""

    def __init__(self, ref, idx):
        self.ref = ref
        self.idx = idx

    def descr(self):
        return (self.ref.name, _norm(self.idx))

    def read(self):
        return self.ref.data[_np_index(self.idx)]

    def write(self, val):
        self.ref.data[_np_index(self.idx)] = np.asarray(val)


class _At:
    def __init__(self, ref):
        self.ref = ref

    def __getitem__(self, idx):
        return _View(self.ref, idx)


class StubRef:
    """Numpy-backed stand-in for a Pallas ref (VMEM/SMEM/ANY alike)."""

    def __init__(self, data, name):
        self.data = np.asarray(data)
        self.name = name

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def at(self):
        return _At(self)

    def __getitem__(self, idx):
        return self.data[_np_index(idx)]

    def __setitem__(self, idx, val):
        self.data[_np_index(idx)] = np.asarray(val)


class Ledger:
    """Per-semaphore copy bookkeeping: the contract being proved."""

    def __init__(self):
        self.pending = {}          # sem descriptor -> FIFO of copy descrs
        self.raw_findings = []     # (rule, detail) tuples
        self.in_flight = 0
        self.max_in_flight = 0
        self.issues = 0
        self.waits = 0

    def issue(self, sem_key, descr):
        q = self.pending.setdefault(sem_key, [])
        if q:
            self.raw_findings.append((
                "slot-overwrite",
                f"copy {descr} started on semaphore {sem_key} while "
                f"{q[0]} is still in flight"))
        q.append(descr)
        self.issues += 1
        self.in_flight += 1
        self.max_in_flight = max(self.max_in_flight, self.in_flight)

    def wait(self, sem_key, descr):
        q = self.pending.setdefault(sem_key, [])
        self.waits += 1
        if not q:
            self.raw_findings.append((
                "wait-before-issue",
                f"wait for {descr} on semaphore {sem_key} with no copy "
                f"in flight"))
            return
        got = q.pop(0)
        self.in_flight -= 1
        if got != descr:
            self.raw_findings.append((
                "wait-descriptor-mismatch",
                f"semaphore {sem_key}: issuer posted {got}, waiter "
                f"recomputed {descr}"))

    def finish(self, n_slots, promised):
        for k, q in self.pending.items():
            for d in q:
                self.raw_findings.append((
                    "unwaited-dma",
                    f"copy {d} on semaphore {k} never awaited"))
        if self.max_in_flight > n_slots:
            self.raw_findings.append((
                "in-flight-exceeds-slots",
                f"peak {self.max_in_flight} copies in flight with only "
                f"{n_slots} scratch slot(s)"))
        if promised is not None and self.max_in_flight < promised:
            self.raw_findings.append((
                "pipeline-under-depth",
                f"peak in-flight depth {self.max_in_flight} never "
                f"reached the promised {promised}"))


class _StubCopy:
    def __init__(self, ledger, src, dst, sem):
        self.ledger = ledger
        self.src = src
        self.dst = dst
        self.sem_key = sem.descr() if isinstance(sem, _View) else \
            (sem.name, ())
        self.descr = (self.src.descr(), self.dst.descr())

    def start(self):
        self.ledger.issue(self.sem_key, self.descr)
        # Data moves at start time.  A correct kernel never overwrites a
        # live slot, so eager movement is equivalent; an incorrect one
        # already produced a slot-overwrite finding above.
        self.dst.write(self.src.read())

    def wait(self):
        self.ledger.wait(self.sem_key, self.descr)


class _PLStub:
    """Eager ``pl``: concrete program ids, real slices, executed
    ``when``."""

    def __init__(self):
        self.grid_point = (0, 0, 0)

    def program_id(self, i):
        return jnp.int32(self.grid_point[i])

    @staticmethod
    def ds(start, size):
        return _DS(start, size)

    @staticmethod
    def when(cond):
        def deco(f):
            if bool(cond):
                f()
            return f
        return deco


class _PltpuStub:
    def __init__(self, ledger):
        self.ledger = ledger

    def make_async_copy(self, src, dst, sem):
        if isinstance(src, StubRef):
            src = _View(src, (Ellipsis,))
        if isinstance(dst, StubRef):
            dst = _View(dst, (Ellipsis,))
        return _StubCopy(self.ledger, src, dst, sem)


class _LaxStub:
    """``jax.lax`` with ``fori_loop`` unrolled to a Python loop so the
    per-iteration DMA side effects are observed, not traced once."""

    def __getattr__(self, name):
        return getattr(jax.lax, name)

    @staticmethod
    def fori_loop(lo, hi, body, init):
        carry = init
        for i in range(int(lo), int(hi)):
            carry = body(jnp.int32(i), carry)
        return carry


class _JaxStub:
    def __init__(self):
        self.lax = _LaxStub()

    def __getattr__(self, name):
        return getattr(jax, name)


@contextlib.contextmanager
def _patched(modules, pl_stub, pltpu_stub):
    """Swap ``pl``/``pltpu``/``jax`` in each module for the stubs."""
    jax_stub = _JaxStub()
    saved = []
    try:
        for mod in modules:
            for name, stub in (("pl", pl_stub), ("pltpu", pltpu_stub),
                               ("jax", jax_stub)):
                if hasattr(mod, name):
                    saved.append((mod, name, getattr(mod, name)))
                    setattr(mod, name, stub)
        yield
    finally:
        for mod, name, val in reversed(saved):
            setattr(mod, name, val)


# ----------------------------------------------------------------------
# Replay driver
# ----------------------------------------------------------------------

# Replay shape: tiny volume, 4 z-planes × 2 y-bands × 1 chunk grid —
# enough steps to wrap every rotation depth several times while keeping
# a full-suite replay in seconds.
_L, _TY, _CHUNK, _BAND, _WIDTH = 8, 4, 8, 8, 128
_ROWS, _COLS = 32, 256
_GRID = (4, 2, 1)
_MICRO = dict(group=4, gband=8, gwidth=32)


@dataclasses.dataclass(frozen=True)
class ReplayCase:
    """One kernel replay: which variant, at which pipeline shape.

    ``kind`` selects the ref layout and promised depth; ``n_slots`` is
    the scratch rotation size the ledger bounds peak in-flight copies
    by, ``promised`` the depth the variant claims to sustain (``None``
    for variants whose DMAs are conditional on tile activity).
    """

    name: str
    kind: str                      # single|single_micro|single_db|batch|
    #                                batch_micro|batch_db|batch_shared
    pbatch: int = 1
    depth: int = 2
    quantized: bool = False

    @property
    def n_slots(self) -> int:
        return {"single": 1, "single_micro": 1, "single_db": self.depth,
                "batch": 2, "batch_micro": 2, "batch_db": self.depth,
                "batch_shared": 1}[self.kind]

    @property
    def promised(self):
        steps = _GRID[0] * _GRID[1] * _GRID[2]
        if self.kind in ("single", "single_micro"):
            return None            # DMA only under the active flag
        if self.kind == "single_db":
            return min(self.depth, steps)
        if self.kind == "batch_db":
            return min(self.depth, steps * self.pbatch)
        if self.kind == "batch_shared":
            return 1
        return 2 if self.pbatch > 1 else 1


def _default_kernel(case: ReplayCase):
    import repro.kernels.backproject as K

    return {"single": K.backproject_kernel,
            "single_micro": K.backproject_kernel_micro,
            "single_db": K.backproject_kernel_db,
            "batch": K.backproject_kernel_batch,
            "batch_micro": K.backproject_kernel_batch_micro,
            "batch_db": K.backproject_kernel_batch_db,
            "batch_shared": K.backproject_kernel_batch_shared}[case.kind]


def replay(case: ReplayCase, kernel_fn=None, extra_modules=()) -> Ledger:
    """Drive one kernel variant across the replay grid; return its
    ledger.

    ``kernel_fn`` overrides the repo kernel (fixture stubs);
    ``extra_modules`` are additional modules whose ``pl``/``pltpu``/
    ``jax`` globals must be stubbed (the fixture's own module — repo
    helpers it imports still resolve through
    ``repro.kernels.backproject``'s globals, which are always patched).
    """
    import repro.kernels.backproject as K
    from repro.core.backproject import GeomStatic
    from repro.core.geometry import default_geometry, projection_matrices

    geom = default_geometry().scaled(_L)
    gs = GeomStatic.of(geom)
    mats = np.asarray(projection_matrices(geom), np.float32)
    kernel = kernel_fn if kernel_fn is not None else _default_kernel(case)

    rng = np.random.default_rng(0)
    batched = case.kind.startswith("batch")
    P = case.pbatch
    if case.quantized:
        imgs = rng.integers(-127, 128, size=(P, _ROWS, _COLS),
                            dtype=np.int8)
        scl = np.stack([rng.uniform(0.01, 0.1, (P, _ROWS)),
                        rng.uniform(-1.0, 1.0, (P, _ROWS))],
                       axis=1).astype(np.float32)     # (P, 2, rows)
    else:
        imgs = rng.standard_normal((P, _ROWS, _COLS)).astype(np.float32)
        scl = None

    ledger = Ledger()
    pl_stub = _PLStub()
    pltpu_stub = _PltpuStub(ledger)

    kwargs = dict(o_mm=(gs.O, gs.MM), n_u=gs.n_u, n_v=gs.n_v, ty=_TY,
                  chunk=_CHUNK, band=_BAND, width=_WIDTH,
                  quantized=case.quantized)
    if case.kind in ("single_micro", "batch_micro"):
        kwargs.update(group=_MICRO["group"], gband=_MICRO["gband"],
                      gwidth=_MICRO["gwidth"])
    if batched:
        kwargs["pbatch"] = P
    if case.kind in ("single_db", "batch_db"):
        kwargs.update(depth=case.depth, grid_dims=_GRID)

    if batched:
        A_ref = StubRef(mats[:P], "A")
        img_ref = StubRef(imgs, "imgs")
    else:
        A_ref = StubRef(mats[0], "A")
        img_ref = StubRef(imgs[0], "img")
    scl_ref = None
    if case.quantized:
        scl_ref = StubRef(scl if batched else scl[0], "scl")

    # Scratch persists across grid steps — exactly the dimension the
    # rotation ledgers depend on.
    strip_shape = {"single": (_BAND, _WIDTH),
                   "single_micro": (_BAND, _WIDTH),
                   "single_db": (case.depth, _BAND, _WIDTH),
                   "batch": (2, _BAND, _WIDTH),
                   "batch_micro": (2, _BAND, _WIDTH),
                   "batch_db": (case.depth, _BAND, _WIDTH),
                   "batch_shared": (P, _BAND, _WIDTH)}[case.kind]
    strip_ref = StubRef(np.zeros(strip_shape, imgs.dtype), "strip")
    acc_ref = StubRef(np.zeros((_TY, _CHUNK), np.float32), "acc")
    sems = StubRef(np.zeros(max(case.n_slots, 1), np.int32), "sems")

    modules = [K] + [m for m in extra_modules if m is not K]
    with _patched(modules, pl_stub, pltpu_stub):
        for z, y, x in itertools.product(*map(range, _GRID)):
            pl_stub.grid_point = (z, y, x)
            vol_in = StubRef(np.zeros((1, _TY, _CHUNK), np.float32),
                             "vol_in")
            vol_out = StubRef(np.zeros((1, _TY, _CHUNK), np.float32),
                              "vol_out")
            refs = [A_ref, img_ref]
            if scl_ref is not None:
                refs.append(scl_ref)
            refs += [vol_in, vol_out, strip_ref]
            if batched:
                refs.append(acc_ref)
            refs.append(sems)
            kernel(*refs, **kwargs)
    ledger.finish(case.n_slots, case.promised)
    return ledger


def builtin_cases() -> list:
    """The full replay space for the repo's seven kernel variants."""
    cases = [
        ReplayCase("single", "single"),
        ReplayCase("single_micro", "single_micro"),
        ReplayCase("batch_shared_p4", "batch_shared", pbatch=4),
        ReplayCase("batch_int8_p4", "batch", pbatch=4, quantized=True),
        ReplayCase("batch_micro_p4", "batch_micro", pbatch=4),
    ]
    for depth in (2, 3, 4):
        cases.append(ReplayCase(f"single_db_d{depth}", "single_db",
                                depth=depth))
    for pb in (4, 3):              # 3: the remainder-group tail shape
        cases.append(ReplayCase(f"batch_p{pb}", "batch", pbatch=pb))
        for depth in (2, 3, 4):
            cases.append(ReplayCase(f"batch_db_p{pb}_d{depth}",
                                    "batch_db", pbatch=pb, depth=depth))
    return cases


def _ledger_findings(name: str, ledger: Ledger) -> list:
    return [Finding("ledger", rule, name, detail)
            for rule, detail in ledger.raw_findings]


def replay_fixture(path: str):
    """Replay a fixture module (``kernel`` callable + ``SPEC`` dict).

    ``SPEC`` carries the :class:`ReplayCase` fields (``kind`` required;
    ``pbatch``/``depth``/``quantized`` optional) — the contract under
    which the fixture kernel claims to operate, which the ledger then
    checks it against.
    """
    spec_obj = importlib.util.spec_from_file_location("_lint_fixture",
                                                      path)
    mod = importlib.util.module_from_spec(spec_obj)
    spec_obj.loader.exec_module(mod)
    spec = dict(mod.SPEC)
    case = ReplayCase(name=spec.get("name", "fixture"),
                      kind=spec["kind"],
                      pbatch=int(spec.get("pbatch", 1)),
                      depth=int(spec.get("depth", 2)),
                      quantized=bool(spec.get("quantized", False)))
    ledger = replay(case, kernel_fn=mod.kernel, extra_modules=(mod,))
    return _ledger_findings(f"{path}:{case.name}", ledger), ledger


def run_ledger_pass(fixture=None, cases=None) -> PassResult:
    """Run the DMA-ledger pass: the builtin suite, or one fixture."""
    findings, notes, checked = [], [], 0
    if fixture is not None:
        fx_findings, ledger = replay_fixture(fixture)
        findings += fx_findings
        checked += 1
        notes.append(f"fixture {fixture}: issues={ledger.issues} "
                     f"waits={ledger.waits} "
                     f"max_in_flight={ledger.max_in_flight}")
        return PassResult("ledger", findings, checked, notes)
    for case in (cases if cases is not None else builtin_cases()):
        ledger = replay(case)
        checked += 1
        findings += _ledger_findings(case.name, ledger)
        notes.append(f"{case.name}: issues={ledger.issues} "
                     f"waits={ledger.waits} "
                     f"max_in_flight={ledger.max_in_flight}")
        if ledger.issues == 0 and case.kind not in ("single",
                                                    "single_micro"):
            findings.append(Finding(
                "ledger", "vacuous-replay", case.name,
                "replay executed zero DMAs — the case proves nothing"))
    return PassResult("ledger", findings, checked, notes)
