"""Trace-hygiene pass: AST rules for the retrace/warn bug classes.

The repo's history names the failure modes this pass guards (PR 2's
per-call re-jit, PR 4/5's silent flag shedding): they are all *source
shapes*, so an AST walk proves their absence without running anything.

Rules (suppress a deliberate site with ``# lint: ok(<rule>)`` on the
flagged line):

* ``jit-in-fn`` — a ``jit(...)`` call (or ``@jit``-decorated nested
  def) inside a function body.  Each call builds a fresh jitted
  callable with an empty compilation cache, so a hot path pays a full
  retrace per invocation — PR 2's bug.  Allowed: module/class scope,
  and one-time construction assigned to a ``self`` attribute (an
  ``__init__`` building the instance's stable step function).
* ``warn-stacklevel`` — ``warnings.warn`` without ``stacklevel``: the
  warning points at the library line instead of the caller, and
  ``filterwarnings`` dedup by location collapses distinct callers.
* ``mutable-default`` — a mutable literal (``[]``/``{}``/``set()``
  /``list()``/``dict()``) as a parameter default: one shared instance
  across calls.
* ``nonhashable-static`` — a parameter named in a jit wrapper's
  ``static_argnames`` (or positioned by ``static_argnums``) whose
  default is a mutable literal: the first defaulted call raises
  ``unhashable type`` — at runtime, on the path that happens to
  default.
* ``unused-import`` — an import binding never referenced in the
  module.  Deliberate re-exports are NOT findings: names listed in the
  module's ``__all__`` (the ``repro/api.py`` facade idiom), redundant
  aliases (``from m import x as x``), lines carrying a ``# noqa``
  marker, and ``from __future__`` imports are all recognised as
  intentional.  Side-effect imports without any of those markers are
  what this rule exists to make explicit.

The static walk is paired with a runtime retrace counter: the
``retrace_counter`` fixture in ``tests/conftest.py`` reads
``_cache_size()`` on the core jitted entry points so tests can assert
"this plan compiles exactly once".
"""

from __future__ import annotations

import ast
import pathlib

from .common import Finding, PassResult

__all__ = ["RULES", "check_source", "run_hygiene_pass"]

RULES = ("jit-in-fn", "warn-stacklevel", "mutable-default",
         "nonhashable-static", "unused-import")

_PRAGMA = "# lint: ok("


def _suppressed(lines, lineno: int, rule: str) -> bool:
    """Pragma on the flagged line or the line directly above it."""
    token = f"{_PRAGMA}{rule})"
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and token in lines[ln - 1]:
            return True
    return False


def _is_jit(node: ast.expr) -> bool:
    """``jax.jit`` / ``api.jit`` / bare ``jit`` reference."""
    return ((isinstance(node, ast.Attribute) and node.attr == "jit")
            or (isinstance(node, ast.Name) and node.id == "jit"))


def _is_jit_call(node: ast.expr) -> bool:
    return isinstance(node, ast.Call) and _is_jit(node.func)


def _is_mutable_literal(node: ast.expr) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("list", "dict", "set") and not node.args
            and not node.keywords)


def _jit_wrapper_call(node: ast.expr):
    """Return the jit-configuring Call for ``jit(...)`` or
    ``partial(jit, ...)`` expressions, else None."""
    if not isinstance(node, ast.Call):
        return None
    if _is_jit(node.func):
        return node
    fn = node.func
    partial_like = ((isinstance(fn, ast.Name) and fn.id == "partial")
                    or (isinstance(fn, ast.Attribute)
                        and fn.attr == "partial"))
    if partial_like and node.args and _is_jit(node.args[0]):
        return node
    return None


def _static_spec(call: ast.Call):
    """Extract literal ``static_argnames`` / ``static_argnums`` from a
    jit-configuring call; non-literal specs are skipped (not provable
    statically)."""
    names, nums = [], []
    for kw in call.keywords:
        if kw.arg not in ("static_argnames", "static_argnums"):
            continue
        vals = (kw.value.elts
                if isinstance(kw.value, (ast.Tuple, ast.List))
                else [kw.value])
        for v in vals:
            if isinstance(v, ast.Constant):
                if kw.arg == "static_argnames" and isinstance(v.value, str):
                    names.append(v.value)
                elif kw.arg == "static_argnums" and isinstance(v.value,
                                                               int):
                    nums.append(v.value)
    return names, nums


def _defaults_by_arg(fn: ast.FunctionDef):
    """Map parameter name -> (position, default node or None)."""
    args = fn.args
    out = {}
    pos = args.posonlyargs + args.args
    pad = [None] * (len(pos) - len(args.defaults))
    for i, (a, d) in enumerate(zip(pos, pad + list(args.defaults))):
        out[a.arg] = (i, d)
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        out[a.arg] = (None, d)
    return out


class _Walker(ast.NodeVisitor):
    def __init__(self, where: str, lines):
        self.where = where
        self.lines = lines
        self.fn_depth = 0
        self.self_allowed = set()   # id() of jit Calls built onto self
        self.findings = []

    def _flag(self, rule: str, lineno: int, detail: str):
        if not _suppressed(self.lines, lineno, rule):
            self.findings.append(Finding(
                "hygiene", rule, f"{self.where}:{lineno}", detail))

    # -- allowance prescan: self.<attr> = [wrap(] jit(...) [)] --------
    def visit_Assign(self, node: ast.Assign):
        if all(isinstance(t, ast.Attribute)
               and isinstance(t.value, ast.Name) and t.value.id == "self"
               for t in node.targets):
            for sub in ast.walk(node.value):
                if _is_jit_call(sub):
                    self.self_allowed.add(id(sub))
        self.generic_visit(node)

    # -- function defs: defaults, nested-jit decorators, static spec --
    def _visit_fn(self, node):
        for name, (_, default) in _defaults_by_arg(node).items():
            if default is not None and _is_mutable_literal(default):
                self._flag("mutable-default", node.lineno,
                           f"parameter {name!r} of {node.name}() defaults "
                           f"to a shared mutable instance")
        by_arg = _defaults_by_arg(node)
        for deco in node.decorator_list:
            wrapper = _jit_wrapper_call(deco) if isinstance(deco,
                                                            ast.Call) \
                else (deco if _is_jit(deco) else None)
            if wrapper is None:
                continue
            if self.fn_depth > 0:
                self._flag("jit-in-fn", deco.lineno,
                           f"@jit on nested def {node.name}() builds a "
                           f"fresh compilation cache per enclosing call")
            if isinstance(wrapper, ast.Call):
                self._check_static(wrapper, node, by_arg)
        self.fn_depth += 1
        self.generic_visit(node)
        self.fn_depth -= 1

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def _check_static(self, call: ast.Call, fn: ast.FunctionDef, by_arg):
        names, nums = _static_spec(call)
        for name in names:
            entry = by_arg.get(name)
            if entry and entry[1] is not None \
                    and _is_mutable_literal(entry[1]):
                self._flag("nonhashable-static", call.lineno,
                           f"static arg {name!r} of {fn.name}() defaults "
                           f"to an unhashable mutable literal")
        for num in nums:
            for name, (pos, default) in by_arg.items():
                if pos == num and default is not None \
                        and _is_mutable_literal(default):
                    self._flag("nonhashable-static", call.lineno,
                               f"static arg #{num} ({name!r}) of "
                               f"{fn.name}() defaults to an unhashable "
                               f"mutable literal")

    # -- calls: jit-in-fn, warn-stacklevel ----------------------------
    def visit_Call(self, node: ast.Call):
        if _is_jit(node.func) and self.fn_depth > 0 \
                and id(node) not in self.self_allowed:
            self._flag("jit-in-fn", node.lineno,
                       "jit(...) constructed inside a function body — "
                       "fresh compilation cache per call")
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr == "warn" \
                and isinstance(fn.value, ast.Name) \
                and fn.value.id == "warnings":
            if not any(kw.arg == "stacklevel" for kw in node.keywords):
                self._flag("warn-stacklevel", node.lineno,
                           "warnings.warn without stacklevel points at "
                           "the library, not the caller")
        self.generic_visit(node)


def _dunder_all(tree) -> set[str]:
    """String literals assigned (or ``+=``-extended) into ``__all__``."""
    exported = set()
    for node in ast.walk(tree):
        value = None
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in node.targets):
            value = node.value
        elif isinstance(node, ast.AugAssign) \
                and isinstance(node.target, ast.Name) \
                and node.target.id == "__all__":
            value = node.value
        if isinstance(value, (ast.List, ast.Tuple, ast.Set)):
            for elt in value.elts:
                if isinstance(elt, ast.Constant) \
                        and isinstance(elt.value, str):
                    exported.add(elt.value)
    return exported


def _check_unused_imports(where: str, tree, lines) -> list:
    """The ``unused-import`` rule: import bindings nothing references.

    A binding counts as *deliberately* kept when the module exports it
    through ``__all__`` (the facade re-export idiom), when it uses the
    redundant-alias form (``from m import x as x`` / ``import m as m``),
    or when the import line carries a ``# noqa`` marker (the
    pre-existing convention for side-effect imports).
    """
    exported = _dunder_all(tree)
    used = {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}
    findings = []

    def flag(bound: str, lineno: int, what: str):
        if bound in used or bound in exported:
            return
        line = lines[lineno - 1] if 1 <= lineno <= len(lines) else ""
        if "# noqa" in line:
            return
        if _suppressed(lines, lineno, "unused-import"):
            return
        findings.append(Finding(
            "hygiene", "unused-import", f"{where}:{lineno}",
            f"{what} is never used; re-export it via __all__, mark the "
            f"line # noqa, or drop it"))

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.asname is not None and alias.asname == alias.name:
                    continue            # import m as m — explicit re-export
                bound = alias.asname or alias.name.split(".")[0]
                flag(bound, node.lineno, f"import {alias.name!r}")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for alias in node.names:
                if alias.name == "*":
                    continue
                if alias.asname is not None and alias.asname == alias.name:
                    continue            # from m import x as x — re-export
                bound = alias.asname or alias.name
                flag(bound, node.lineno,
                     f"imported name {bound!r}")
    return findings


def check_source(where: str, text: str) -> list:
    """Run all hygiene rules over one source blob."""
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding("hygiene", "syntax-error", f"{where}:{e.lineno}",
                        str(e))]
    walker = _Walker(where, text.splitlines())
    walker.visit(tree)
    walker.findings += _check_unused_imports(where, tree,
                                             text.splitlines())
    # Module-level statics: x = jit(f, static_argnames=...) naming a
    # module function whose static default is mutable.
    fns = {n.name: n for n in ast.walk(tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    for node in ast.walk(tree):
        wrapper = _jit_wrapper_call(node)
        if wrapper is None:
            continue
        target = None
        args = [a for a in wrapper.args if not _is_jit(a)]
        if args and isinstance(args[0], ast.Name):
            target = fns.get(args[0].id)
        if target is not None:
            walker._check_static(wrapper, target,
                                 _defaults_by_arg(target))
    return walker.findings


def run_hygiene_pass(root="src") -> PassResult:
    """Walk every ``.py`` under ``root`` and apply the rules."""
    rootp = pathlib.Path(root)
    findings, checked = [], 0
    for path in sorted(rootp.rglob("*.py")):
        text = path.read_text()
        findings += check_source(str(path), text)
        checked += 1
    return PassResult("hygiene", findings, checked)
