"""``python -m repro.analysis.lint`` — run the contract checker.

Runs the four passes (or a ``--passes`` subset), prints one JSON
document (``{"ok", "findings", "passes"}``) to stdout, and exits
nonzero when any finding survives.  ``--kernel-fixture`` replays a
single kernel stub module through the DMA ledger instead of the builtin
suite; ``--tuned-config`` audits a single cache file instead of the
tune dir — both are how the seeded known-bad fixtures under
``tests/lint_fixtures/`` are exercised.
"""

from __future__ import annotations

import argparse
import json
import sys

from .budget import screen_candidate_spaces
from .cache_audit import audit_cache_file, run_cache_audit_pass
from .common import PassResult
from .hygiene import run_hygiene_pass
from .ledger import run_ledger_pass

PASSES = ("ledger", "budget", "hygiene", "cache")


def _budget_pass() -> PassResult:
    findings, checked = screen_candidate_spaces()
    return PassResult("budget", findings, checked)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Kernel contract checker: DMA ledger, VMEM budget, "
                    "trace hygiene, tuned-cache audit.")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help=f"comma-separated subset of {PASSES}")
    ap.add_argument("--root", default="src",
                    help="source tree the hygiene pass walks")
    ap.add_argument("--tune-dir", default=None,
                    help="cache dir to audit (default: tune_dir())")
    ap.add_argument("--kernel-fixture", default=None, metavar="PATH",
                    help="replay this kernel stub module (kernel + SPEC) "
                         "through the DMA ledger instead of the builtin "
                         "suite")
    ap.add_argument("--tuned-config", default=None, metavar="PATH",
                    help="audit this one cache file instead of the tune "
                         "dir")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the JSON report here")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 when findings survive (the default; "
                         "kept explicit for CI)")
    args = ap.parse_args(argv)

    selected = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = [p for p in selected if p not in PASSES]
    if unknown:
        ap.error(f"unknown pass(es) {unknown}; choose from {PASSES}")

    results = []
    if "ledger" in selected:
        results.append(run_ledger_pass(fixture=args.kernel_fixture))
    if "budget" in selected:
        results.append(_budget_pass())
    if "hygiene" in selected:
        results.append(run_hygiene_pass(args.root))
    if "cache" in selected:
        if args.tuned_config is not None:
            findings = audit_cache_file(args.tuned_config)
            results.append(PassResult("cache", findings, 1))
        else:
            results.append(run_cache_audit_pass(args.tune_dir))

    findings = [f for r in results for f in r.findings]
    report = {"ok": not findings,
              "findings": [f.as_dict() for f in findings],
              "passes": [r.as_dict() for r in results]}
    text = json.dumps(report, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(text + "\n")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
