"""Tuned-cache audit: re-validate persisted decisions against today's
planner.

``load_tuned`` already rejects wrong-schema and corrupt files — but
*silently*, by treating them as untuned, and it never re-checks a
schema-valid config against the current planner.  A config tuned before
a planner or kernel change can therefore be schema-v5-clean yet name a
window the planner now proves undersized (silent tap loss, PR 4/5's
bug class), a strategy the resolver would quietly shed options from, or
a working set over the VMEM screen.  This pass makes all of that a lint
finding; the same :func:`audit_tuned_config` runs inside the
``Dispatcher`` at resolve time, where a failing cached config produces
one structured warning and falls back to in-situ selection
(DESIGN.md §11) instead of executing a stale window.
"""

from __future__ import annotations

import json
import re
from pathlib import Path

import numpy as np

from repro.core.backproject import STRATEGIES, GeomStatic

from .budget import WIRE_ITEMSIZE, estimate_for_pallas_config
from .common import Finding, PassResult

__all__ = ["parse_cache_key", "geometry_for", "audit_tuned_config",
           "audit_cache_file", "run_cache_audit_pass"]

# cache_key() layout: ct-L{L}-u{n_u}-v{n_v}-O{O:g}-MM{MM:g}--{backend}--
# {device_kind}.  O/MM are %g floats (may carry '-' or exponents), so
# the geometry fields anchor on their labels, non-greedily.
_KEY_RE = re.compile(
    r"^ct-L(?P<L>\d+)-u(?P<u>\d+)-v(?P<v>\d+)"
    r"-O(?P<O>.+?)-MM(?P<MM>.+?)--(?P<backend>.+?)--(?P<device>.+)$")

# Planner validation is exact-per-matrix; auditing every projection of a
# production scan at resolve time would cost more than the sweep it
# guards.  The footprint extremes move smoothly with angle, so an even
# angular sample bounds them tightly.
_MAX_AUDIT_MATS = 8


def parse_cache_key(stem: str):
    """``(GeomStatic, backend, device_kind)`` from a cache-file stem, or
    ``None`` when the name is not a cache key."""
    m = _KEY_RE.match(stem)
    if not m:
        return None
    try:
        gs = GeomStatic(L=int(m["L"]), n_u=int(m["u"]), n_v=int(m["v"]),
                        O=float(m["O"]), MM=float(m["MM"]))
    except ValueError:
        return None
    return gs, m["backend"], m["device"]


def geometry_for(gs: GeomStatic):
    """Full ``Geometry`` matching ``gs``, when one is reconstructible.

    A cache file stores only the static key, not the full geometry; the
    repo's geometries are all ``default_geometry().scaled(L)``, so that
    round-trip is attempted and verified.  Returns ``None`` when the key
    belongs to some other parameterisation — the audit then runs its
    static checks only.
    """
    from repro.core.geometry import default_geometry

    try:
        geom = default_geometry().scaled(gs.L)
    except Exception:
        return None
    return geom if GeomStatic.of(geom) == gs else None


def _sampled_matrices(geom):
    from repro.core.geometry import projection_matrices

    mats = np.asarray(projection_matrices(geom), np.float64)
    if len(mats) > _MAX_AUDIT_MATS:
        idx = np.linspace(0, len(mats) - 1, _MAX_AUDIT_MATS).astype(int)
        mats = mats[idx]
    return mats


def audit_tuned_config(gs: GeomStatic, cfg, geom=None) -> list:
    """Reasons this TunedConfig must not be replayed; empty when sound.

    Static checks always run (strategy/option-key membership, wire
    dtype, the VMEM byte model); with a full ``geom`` the planner
    re-validates the jnp window and the Pallas tile/micro/shared-window
    coverage exactly as the execution wrappers would.
    """
    from repro.tune.cache import _PALLAS_KEYS, _STRATEGY_KEYS

    reasons = []
    if cfg.strategy not in STRATEGIES:
        reasons.append(f"strategy {cfg.strategy!r} is not a known jnp "
                       f"strategy {STRATEGIES}")
        return reasons
    allowed = _STRATEGY_KEYS[cfg.strategy]
    opts = dict(cfg.opts or {})
    stray = sorted(k for k in opts if k not in allowed)
    if stray:
        reasons.append(f"opts {stray} are not accepted by strategy "
                       f"{cfg.strategy!r} — the resolver would shed them")
    wire = opts.get("strip_dtype", "float32")
    if wire not in WIRE_ITEMSIZE:
        reasons.append(f"opts strip_dtype {wire!r} is not a known wire "
                       f"dtype {tuple(WIRE_ITEMSIZE)}")
    pallas = dict(cfg.pallas or {})
    if pallas:
        stray = sorted(k for k in pallas if k not in _PALLAS_KEYS)
        if stray:
            reasons.append(f"pallas keys {stray} are unknown to the "
                           f"kernel config surface {_PALLAS_KEYS}")
        pwire = pallas.get("strip_dtype", "float32")
        if pwire not in WIRE_ITEMSIZE:
            reasons.append(f"pallas strip_dtype {pwire!r} is not a known "
                           f"wire dtype {tuple(WIRE_ITEMSIZE)}")
        else:
            est = estimate_for_pallas_config(gs, pallas)
            if not est.fits:
                reasons.append(
                    f"pallas config working set {est.vmem_total} B "
                    f"exceeds the {est.budget} B VMEM budget "
                    f"(strips={est.strip_bytes}, tile={est.tile_bytes}, "
                    f"onehot={est.onehot_bytes}, "
                    f"scales={est.scale_bytes})")
    if geom is None:
        return reasons

    mats = _sampled_matrices(geom)
    from repro.core.backproject import validate_strip_opts

    try:
        validate_strip_opts(geom, mats, cfg.strategy,
                            {k: v for k, v in opts.items()
                             if k in allowed})
    except ValueError as e:
        reasons.append(f"jnp window fails the current planner: {e}")
    if pallas and pallas.get("strip_dtype",
                             "float32") in WIRE_ITEMSIZE:
        from repro.kernels.backproject_ops import (clamp_tiles,
                                                   shared_window_dims,
                                                   validate_strip_config)

        ty, chunk, band, width = clamp_tiles(
            gs, int(pallas.get("ty", 8)), int(pallas.get("chunk", 128)),
            int(pallas.get("band", 16)), int(pallas.get("width", 512)))
        micro_kw = {}
        if pallas.get("micro", False):
            micro_kw = dict(micro=True,
                            micro_group=int(pallas.get("micro_group", 8)),
                            micro_band=int(pallas.get("micro_band", 8)),
                            micro_width=int(pallas.get("micro_width",
                                                       32)))
        for A in mats:
            try:
                validate_strip_config(geom, A, ty=ty, chunk=chunk,
                                      band=band, width=width, **micro_kw)
            except ValueError as e:
                reasons.append(
                    f"pallas tile fails the current planner: {e}")
                break
        if pallas.get("shared_window", False):
            try:
                shared_window_dims(
                    geom, mats, ty=ty, chunk=chunk,
                    pbatch=max(1, int(pallas.get("pbatch", 1))),
                    shared_band=pallas.get("shared_band"),
                    shared_width=pallas.get("shared_width"))
            except ValueError as e:
                reasons.append(
                    f"shared window fails the current planner: {e}")
    return reasons


def audit_cache_file(path) -> list:
    """Findings for one ``.repro_tune/`` JSON file."""
    from repro.tune.cache import TUNE_SCHEMA_VERSION, TunedConfig

    path = Path(path)
    where = str(path)
    parsed = parse_cache_key(path.stem)
    if parsed is None:
        return [Finding("cache", "unparseable-key", where,
                        "file name is not a cache key — load_tuned can "
                        "never hit it; delete or re-tune")]
    gs, _backend, _device = parsed
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [Finding("cache", "corrupt-file", where,
                        f"not valid JSON ({e}); load_tuned silently "
                        f"treats this as untuned")]
    if not isinstance(data, dict) \
            or data.get("version") != TUNE_SCHEMA_VERSION:
        return [Finding(
            "cache", "stale-schema", where,
            f"schema version {data.get('version') if isinstance(data, dict) else None!r} "
            f"!= current {TUNE_SCHEMA_VERSION}; load_tuned silently "
            f"ignores it — re-tune or delete")]
    try:
        cfg = TunedConfig(**data)
    except TypeError as e:
        return [Finding("cache", "malformed-config", where,
                        f"fields do not load into TunedConfig ({e})")]
    return [Finding("cache", "planner-invalid", where, reason)
            for reason in audit_tuned_config(gs, cfg,
                                             geom=geometry_for(gs))]


def run_cache_audit_pass(dirpath=None) -> PassResult:
    """Audit every JSON file under the tune dir (default
    ``tune_dir()``)."""
    from repro.tune.cache import tune_dir

    d = Path(dirpath) if dirpath is not None else tune_dir()
    findings, checked, notes = [], 0, []
    if not d.is_dir():
        notes.append(f"tune dir {d} does not exist — nothing cached")
        return PassResult("cache", findings, checked, notes)
    for path in sorted(d.glob("*.json")):
        findings += audit_cache_file(path)
        checked += 1
    if checked == 0:
        notes.append(f"tune dir {d} holds no cache files")
    return PassResult("cache", findings, checked, notes)
