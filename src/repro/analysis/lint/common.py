"""Shared finding record for the kernel contract checker.

Every lint pass emits :class:`Finding` rows; the CLI aggregates them
into one JSON document and exits nonzero when any survive.  A finding
is a *proved* contract violation (the ledger replay drove the actual
kernel logic, the budget model computed actual bytes, the AST node is
on disk), never a heuristic score — the passes are designed so the
clean tree reports zero findings and stays the false-positive gate.
"""

from __future__ import annotations

import dataclasses

__all__ = ["Finding", "PassResult"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation.

    ``pass_name`` is the emitting pass (``ledger``/``budget``/
    ``hygiene``/``cache``), ``rule`` a stable machine-readable
    identifier, ``where`` the subject (kernel variant label, file:line,
    cache file, config label) and ``detail`` the human explanation with
    the concrete numbers that prove the violation.
    """

    pass_name: str
    rule: str
    where: str
    detail: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"[{self.pass_name}:{self.rule}] {self.where}: {self.detail}"


@dataclasses.dataclass
class PassResult:
    """One pass's outcome: findings plus what was actually checked.

    ``checked`` counts the units the pass proved clean (kernel-variant
    replays, configs screened, files walked, cache entries audited) so
    an accidentally-vacuous pass — zero findings because zero work — is
    visible in the report instead of reading as a clean bill.
    """

    pass_name: str
    findings: list
    checked: int
    notes: list = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        return {"pass": self.pass_name, "checked": self.checked,
                "findings": [f.as_dict() for f in self.findings],
                "notes": list(self.notes)}
