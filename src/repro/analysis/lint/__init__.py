"""Kernel contract checker: static analysis for the Pallas stack.

Four passes, each proving a contract the runtime checks silently or
not at all (DESIGN.md §13):

* :mod:`.ledger` — replays every kernel variant's DMA issue/wait logic
  against recording stubs; proves semaphore balance, producer/consumer
  origin agreement, slot liveness, and pipeline-depth bounds.
* :mod:`.budget` — the single VMEM/SMEM byte model behind both the
  tuner's candidate screen (``pallas_batch_fits_vmem``) and lint.
* :mod:`.hygiene` — AST rules for the retrace/warn bug classes
  (jit-in-fn, warn-stacklevel, mutable-default, nonhashable-static).
* :mod:`.cache_audit` — re-validates persisted ``.repro_tune/``
  decisions against the current planner; shared with the
  ``Dispatcher``'s resolve-time audit.

CLI: ``python -m repro.analysis.lint`` emits one JSON document of
structured findings and exits nonzero when any survive.
"""

from .budget import (VMEM_BUDGET_BYTES, VmemEstimate,  # noqa: F401
                     batch_vmem_estimate, estimate_for_pallas_config)
from .cache_audit import (audit_cache_file,  # noqa: F401
                          audit_tuned_config, run_cache_audit_pass)
from .common import Finding, PassResult  # noqa: F401
from .hygiene import check_source, run_hygiene_pass  # noqa: F401
from .ledger import (Ledger, ReplayCase, StubRef,  # noqa: F401
                     builtin_cases, replay, replay_fixture,
                     run_ledger_pass)
