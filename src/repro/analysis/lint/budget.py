"""VMEM/SMEM byte model for the Pallas kernel configs — THE model.

One implementation of the per-config working-set accounting, used by
both the autotuner's candidate screen (:func:`repro.tune.space
.pallas_batch_fits_vmem` delegates here) and the lint budget pass, so
the two can never drift: a config the tuner admits is a config the
linter prices with the same bytes, and vice versa (PR 6's hard-coded
traffic-model tile is the bug class this kills).

The model mirrors what the wrappers actually allocate
(``repro.kernels.backproject_ops`` / ``backproject.py``):

* **strip slots** — ``max(pbatch, depth) · band · width · itemsize``.
  The plain batch kernel rotates 2 slots, the pipelined variant
  ``db_depth``, the shared-window kernel one ``(pbatch, band, width)``
  slab; an ANY-space promotion may keep up to ``pbatch`` resident, so
  the screen prices the larger of the two (the tuner's historical
  conservative rule, kept bit-for-bit).
* **volume tile** — aliased in/out ``(1, ty, chunk)`` f32 pair plus the
  f32 accumulator: ``3 · ty · chunk · 4``.
* **one-hot selectors** — ``rowsel (ty·chunk, band)`` and ``colsel
  (ty·chunk, width)`` f32 temporaries of :func:`_tile_contrib`.
* **int8 scale sideband** — the ``(pbatch, 2, rows)`` f32 scale/offset
  block is VMEM-resident for the whole call (constant BlockSpec), with
  ``rows`` the *padded* row count: ``max(band, n_v + 2)`` rounded up to
  the wire dtype's sublane tile (32 rows for the 1-byte wire —
  ``repro.kernels.backproject_ops._SUBLANE``).
* **SMEM** — the ``(pbatch, 3, 4)`` f32 matrix stack (reported, never
  binding: SMEM is KBs and the stack is tiny).
"""

from __future__ import annotations

import dataclasses

from repro.core.backproject import GeomStatic

__all__ = ["VMEM_BUDGET_BYTES", "WIRE_ITEMSIZE", "VmemEstimate",
           "batch_vmem_estimate", "estimate_for_pallas_config",
           "screen_candidate_spaces"]

# Usable per-core VMEM budget for candidate screening.  Half the 16 MB
# physical VMEM: the grid pipeline needs headroom for the in-flight
# volume tiles and the compiler's own temporaries.  (Moved here from
# repro.tune.space — the tuner now reads it from the model.)
VMEM_BUDGET_BYTES = 8 * 2 ** 20

# Strip wire itemsize per ``strip_dtype`` option — the same table
# ``repro.core.backproject.strip_wire_dtype`` validates against.
WIRE_ITEMSIZE = {"float32": 4, "bfloat16": 2, "int8": 1}

# Sublane tile per wire itemsize — mirrors (and is asserted in tests
# against) ``repro.kernels.backproject_ops._SUBLANE``; duplicated here
# so the byte model stays importable without pulling the kernel stack.
_SUBLANE = {1: 32, 2: 16, 4: 8}


def _padded_rows(gs: GeomStatic, band: int, itemsize: int) -> int:
    """Padded detector row count for a wire itemsize — the row shape
    the ``(P, 2, rows)`` scale sideband is allocated at
    (``backproject_ops._encode_padded``'s rounding)."""
    sub = _SUBLANE.get(itemsize, 8)
    rows = max(band, gs.n_v + 2)
    return rows + (-rows) % sub


@dataclasses.dataclass(frozen=True)
class VmemEstimate:
    """Per-config VMEM/SMEM byte accounting, term by term."""

    strip_bytes: int
    tile_bytes: int
    onehot_bytes: int
    scale_bytes: int
    smem_bytes: int
    budget: int = VMEM_BUDGET_BYTES

    @property
    def vmem_total(self) -> int:
        return (self.strip_bytes + self.tile_bytes + self.onehot_bytes
                + self.scale_bytes)

    @property
    def fits(self) -> bool:
        return self.vmem_total <= self.budget

    def as_dict(self) -> dict:
        return {**dataclasses.asdict(self), "vmem_total": self.vmem_total,
                "fits": self.fits}


def batch_vmem_estimate(gs: GeomStatic, *, pbatch: int, ty: int,
                        chunk: int, band: int, width: int, depth: int = 2,
                        itemsize: int | None = None,
                        strip_dtype: str = "float32") -> VmemEstimate:
    """Byte model for one batched-kernel configuration.

    ``itemsize`` overrides the ``strip_dtype``-derived wire width (the
    tuner's historical calling convention); the ``(P, 2, rows)`` f32
    scale sideband is counted whenever the wire is 1 byte — the int8
    path always carries it.
    """
    if itemsize is None:
        try:
            itemsize = WIRE_ITEMSIZE[str(strip_dtype)]
        except KeyError:
            raise ValueError(
                f"unknown strip_dtype {strip_dtype!r}; want one of "
                f"{tuple(WIRE_ITEMSIZE)}") from None
    strips = max(pbatch, depth) * band * width * itemsize
    tile = 3 * ty * chunk * 4
    onehot = ty * chunk * (band + width) * 4
    scales = (pbatch * 2 * _padded_rows(gs, band, itemsize) * 4
              if itemsize == 1 else 0)
    smem = pbatch * 3 * 4 * 4
    return VmemEstimate(strip_bytes=strips, tile_bytes=tile,
                        onehot_bytes=onehot, scale_bytes=scales,
                        smem_bytes=smem)


def estimate_for_pallas_config(gs: GeomStatic,
                               cfg: dict) -> VmemEstimate:
    """Price a tuned/cached Pallas config dict (``_PALLAS_KEYS`` shape).

    Derives the slot depth from the variant flags exactly as the
    wrappers do: ``db_depth`` slots when ``double_buffer``, a
    ``pbatch``-deep slab when ``shared_window`` (at the explicit
    ``shared_band``/``shared_width`` when pinned, else the 2×-base
    screen the tuner applies before the group planner sizes the real
    slab), 2 rotation slots otherwise.  The tile parameters are clamped
    through :func:`repro.kernels.backproject_ops.clamp_tiles` — the
    model prices the config the kernel would *run*, not the raw dict.
    """
    from repro.kernels.backproject_ops import clamp_tiles

    ty, chunk, band, width = clamp_tiles(
        gs, int(cfg.get("ty", 8)), int(cfg.get("chunk", 128)),
        int(cfg.get("band", 16)), int(cfg.get("width", 512)))
    pbatch = max(1, int(cfg.get("pbatch", 1)))
    strip_dtype = str(cfg.get("strip_dtype", "float32"))
    if cfg.get("shared_window", False):
        band = int(cfg.get("shared_band") or 2 * band)
        width = int(cfg.get("shared_width") or 2 * width)
        _, _, band, width = clamp_tiles(gs, ty, chunk, band, width)
        depth = pbatch
    elif cfg.get("double_buffer", False):
        depth = int(cfg.get("db_depth", 2))
    else:
        depth = 2
    return batch_vmem_estimate(gs, pbatch=pbatch, ty=ty, chunk=chunk,
                               band=band, width=width, depth=depth,
                               strip_dtype=strip_dtype)


# ----------------------------------------------------------------------
# Lint pass: every config the repo can propose must fit the budget
# ----------------------------------------------------------------------

# Geometry scales the budget pass screens the candidate generator at:
# tiny (the test/CI shapes), mid, and the RabbitCT production case.
_SCREEN_SCALES = (8, 32, 512)


def screen_candidate_spaces(extra_configs=()):
    """Budget-screen every Pallas candidate the tuner can propose.

    The generator's own VMEM check and this model are now the same
    function, so a violation here means the *derived* config (after
    ``clamp_tiles`` / shared-window sizing) outgrew what the raw
    candidate was screened at — exactly the drift class this pass
    exists to catch.  ``extra_configs`` adds ``(label, GeomStatic,
    config_dict)`` triples (cache files, CLI ``--tuned-config``) to
    the screen.

    Returns ``(findings, checked)``.
    """
    from repro.core.geometry import default_geometry
    from repro.tune.space import pallas_candidates

    from .common import Finding

    findings, checked = [], 0
    for L in _SCREEN_SCALES:
        gs = GeomStatic.of(default_geometry().scaled(L))
        for cand in pallas_candidates(gs):
            est = estimate_for_pallas_config(gs, dict(cand.opts))
            checked += 1
            if not est.fits:
                findings.append(Finding(
                    "budget", "candidate-over-vmem",
                    f"L={L}:{cand.label}",
                    f"derived working set {est.vmem_total} B exceeds "
                    f"the {est.budget} B screen "
                    f"(strips={est.strip_bytes}, tile={est.tile_bytes}, "
                    f"onehot={est.onehot_bytes}, "
                    f"scales={est.scale_bytes})"))
    for label, gs, cfg in extra_configs:
        est = estimate_for_pallas_config(gs, dict(cfg))
        checked += 1
        if not est.fits:
            findings.append(Finding(
                "budget", "config-over-vmem", str(label),
                f"working set {est.vmem_total} B exceeds the "
                f"{est.budget} B budget (strips={est.strip_bytes}, "
                f"tile={est.tile_bytes}, onehot={est.onehot_bytes}, "
                f"scales={est.scale_bytes})"))
    return findings, checked
