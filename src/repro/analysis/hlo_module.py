"""While-aware HLO module analysis: loop-weighted flops/bytes/collectives.

``compiled.cost_analysis()`` traverses each computation once, so anything
inside a ``while`` body (every ``lax.scan``: layer stacks, attention KV
blocks, SSM chunk scans, grad accumulation) is undercounted by its trip
count — for a 94-layer scanned model that is a ~94x error.  XLA:CPU
records ``backend_config={"known_trip_count":{"n":...}}`` on every while
it can bound; this module parses the optimised HLO into its computation
graph (with a per-computation symbol table, since operand shapes are not
inlined) and produces **loop-weighted** totals:

* ``flops``        — 2*out*K per dot/convolution, trip-count multiplied,
                     plus 1/elem at fusion boundaries (the minor term);
* ``bytes``        — operands+outputs per top-level instruction (same
                     convention as XLA "bytes accessed"; fusion internals
                     excluded — they live in registers);
* ``collectives``  — per-kind bytes moved (all-reduce doubled: ring =
                     reduce-scatter + all-gather), trip-count multiplied;
* ``census``       — paper-style op classes (Table 2 analogue).

Validated in ``tests/test_hlo_analysis.py``: loop-weighted counts on a
scanned model equal plain counts on its unrolled twin.
"""

from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass, field

from .hlo import _CLASS, _DTYPE_BYTES, COLLECTIVES

__all__ = ["HloModule", "analyze_module"]

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\{\s*$")
_LHS = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_SHAPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NAME = re.compile(r"%([\w\.\-]+)")
_CALLED = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPCODE = re.compile(r"^\s*([a-z0-9\-\$_]+)\(")

_SKIP_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
             "bitcast", "after-all", "partition-id", "replica-id"}


def _shapes_of(segment: str):
    """[(dtype, dims-list)] for every shape literal in ``segment``."""
    out = []
    for dt, dims in _SHAPE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_elems(shapes):
    b = e = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        b += n * _DTYPE_BYTES[dt]
        e += n
    return b, e


@dataclass
class Instr:
    name: str
    opcode: str
    out_shapes: list
    operands: list
    tail: str            # text after the operand list (attrs, metadata)
    op_segment: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # %name -> shape list


def _split_op(rhs: str):
    """Split '<type> opcode(operands), attrs' robustly."""
    # Find the opcode: last token before the first '(' that is not part
    # of a shape literal.  Walk tokens.
    m = re.search(r"([a-z][a-z0-9\-\$_]*)\(", rhs)
    if not m:
        return None
    op = m.group(1)
    out_seg = rhs[:m.start()]
    rest = rhs[m.end():]
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return op, out_seg, rest[:i], rest[i + 1:]
    return op, out_seg, rest, ""


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, Computation] = {}
        self.entry: str | None = None
        cur: Computation | None = None
        for raw in text.splitlines():
            s = raw.strip()
            hdr = _COMP_HDR.match(s)
            if hdr:
                cur = Computation(hdr.group(2), bool(hdr.group(1)))
                self.comps[cur.name] = cur
                if cur.is_entry:
                    self.entry = cur.name
                continue
            if s == "}":
                cur = None
                continue
            if cur is None:
                continue
            lm = _LHS.match(s)
            if not lm:
                continue
            name, rhs = lm.group(1), lm.group(2)
            sp = _split_op(rhs)
            if sp is None:
                continue
            op, out_seg, opnd_seg, tail = sp
            out_shapes = _shapes_of(out_seg)
            # operand names only from the operand segment
            operands = _NAME.findall(opnd_seg)
            cur.shapes[name] = out_shapes
            cur.instrs.append(Instr(name, op, out_shapes, operands,
                                    tail, opnd_seg,
                                    is_root=s.startswith("ROOT ")))
        self._memo: dict[str, Counter] = {}

    # ------------------------------------------------------------------
    def _instr_cost(self, comp: Computation, ins: Instr) -> Counter:
        c: Counter = Counter()
        if ins.opcode in _SKIP_OPS:
            return c
        out_b, out_e = _bytes_elems(ins.out_shapes)
        in_shapes = []
        for o in ins.operands:
            in_shapes.extend(comp.shapes.get(o, []))
        in_b, in_e = _bytes_elems(in_shapes)
        # Indexing ops move only the slice, not the addressable operand:
        # a scan writing its ys stack via dynamic-update-slice touches
        # update-sized bytes per step, not the whole stack (counting the
        # full buffer overstated scan-heavy models ~40x — §Perf metric
        # note in EXPERIMENTS.md).
        if ins.opcode == "dynamic-update-slice":
            upd = (_bytes_elems(comp.shapes.get(ins.operands[1], []))[0]
                   if len(ins.operands) > 1 else out_b)
            c["bytes"] += 2 * upd
        elif ins.opcode in ("dynamic-slice", "slice", "broadcast",
                            "iota", "reshape", "transpose", "reverse"):
            c["bytes"] += 2 * out_b
        elif ins.opcode == "gather":
            c["bytes"] += 2 * out_b
            c["gather_bytes"] += out_b     # serialised-access bytes
        elif ins.opcode == "scatter":
            upd = (_bytes_elems(comp.shapes.get(ins.operands[-1], []))[0]
                   if ins.operands else out_b)
            c["bytes"] += 3 * upd          # read+write region + updates
            c["gather_bytes"] += upd
        else:
            c["bytes"] += out_b + in_b

        base = ins.opcode.removesuffix("-start")
        if base in COLLECTIVES and not ins.opcode.endswith("-done"):
            nbytes = out_b if base != "all-reduce" else 2 * out_b
            c[f"coll_{base}"] += nbytes
            c["coll_total"] += nbytes

        if ins.opcode == "fusion":
            # Bytes handled at the call site via _fusion_bytes (loads/
            # stores are slice-aware there); undo the boundary count.
            c["bytes"] -= out_b + in_b

        if ins.opcode in ("dot", "convolution") or \
                (ins.opcode == "custom-call" and "matmul" in ins.tail):
            lhs_dims = (comp.shapes.get(ins.operands[0], [("f32", [])])
                        [0][1] if ins.operands else [])
            md = _DOT_DIMS.search(ins.tail)
            if md and md.group(1):
                k = 1
                for d in md.group(1).split(","):
                    di = int(d)
                    k *= lhs_dims[di] if di < len(lhs_dims) else 1
            else:
                # convolution / opaque matmul: infer K from elem counts.
                k = max(1, in_e // max(out_e, 1))
            c["flops"] += 2 * out_e * k
        elif ins.opcode == "fusion":
            c["flops"] += out_e

        for cls, names in _CLASS.items():
            if ins.opcode in names:
                c[f"census_{cls}"] += 1
                break
        else:
            c["census_other"] += 1
        c["census_total"] += 1
        return c

    _SLICING = ("dynamic-slice", "gather", "slice")

    def _fusion_bytes(self, name: str) -> int:
        """HBM traffic model of one fusion computation.

        Loads: each parameter counts full-size unless *all* its uses are
        slicing ops, in which case the slice outputs count (the fused
        loop only touches those addresses).  Stores: the root counts its
        output, except a root dynamic-update-slice stores only the
        update (in-place loop-carried buffers).
        """
        comp = self.comps.get(name)
        if comp is None:
            return 0, 0
        uses: dict[str, list] = {}
        for ins in comp.instrs:
            for o in ins.operands:
                uses.setdefault(o, []).append(ins)
        by_name = {i.name: i for i in comp.instrs}
        total = 0
        gather_b = 0
        for ins in comp.instrs:
            if ins.opcode != "parameter":
                continue
            u = uses.get(ins.name, [])
            if u and all(x.opcode in self._SLICING for x in u):
                for x in u:
                    b = _bytes_elems(x.out_shapes)[0]
                    total += b
                    if x.opcode == "gather":
                        gather_b += b
            else:
                total += _bytes_elems(ins.out_shapes)[0]

        def store_bytes(instr):
            if instr.opcode == "dynamic-update-slice" \
                    and len(instr.operands) > 1:
                upd = comp.shapes.get(instr.operands[1], [])
                return _bytes_elems(upd)[0]
            return _bytes_elems(instr.out_shapes)[0]

        roots = [i for i in comp.instrs if i.is_root]
        for root in roots:
            if root.opcode == "tuple":
                for o in root.operands:
                    src = by_name.get(o)
                    total += store_bytes(src) if src is not None else 0
            else:
                total += store_bytes(root)
        return total, gather_b

    def _comp_cost(self, name: str) -> Counter:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Counter()      # cycle guard
        comp = self.comps.get(name)
        total: Counter = Counter()
        if comp is None:
            self._memo[name] = total
            return total
        for ins in comp.instrs:
            total.update(self._instr_cost(comp, ins))
            if ins.opcode == "while":
                called = _CALLED.findall(ins.tail)
                m = _TRIP.search(ins.tail)
                trip = int(m.group(1)) if m else 1
                for sub in called:
                    for k, v in self._comp_cost(sub).items():
                        total[k] += v * trip
            elif ins.opcode in ("call", "custom-call", "async-start"):
                for sub in _CALLED.findall(ins.tail):
                    total.update(self._comp_cost(sub))
            elif ins.opcode == "conditional":
                mb = _BRANCHES.search(ins.tail)
                if mb:
                    # Upper bound: assume the costliest branch.
                    costs = [self._comp_cost(b.strip().lstrip("%"))
                             for b in mb.group(1).split(",") if b.strip()]
                    if costs:
                        best = max(costs, key=lambda cc: cc["flops"]
                                   + cc["bytes"])
                        total.update(best)
            elif ins.opcode == "fusion":
                # Bytes: slice-aware loads/stores of the fused loop
                # (a fused dynamic-slice reads its slice, not its whole
                # operand; a fused in-place update-slice root stores the
                # update).  Census: the fused ops are the "instructions"
                # of the loop body (a gather fused into a loop is still
                # a gather).
                for sub in _CALLED.findall(ins.tail):
                    fb, gb = self._fusion_bytes(sub)
                    total["bytes"] += fb
                    total["gather_bytes"] += gb
                    for k, v in self._comp_cost(sub).items():
                        if k.startswith("census_"):
                            total[k] += v
            # reduce/scatter to_apply: scalar per-element bodies,
            # covered by the boundary cost — intentionally not recursed.
        self._memo[name] = total
        return total

    def analyze(self) -> dict:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        c = self._comp_cost(self.entry)
        coll = {k.removeprefix("coll_"): v for k, v in c.items()
                if k.startswith("coll_")}
        coll.setdefault("total", 0)
        census = {k.removeprefix("census_"): v for k, v in c.items()
                  if k.startswith("census_")}
        return {
            "flops": float(c["flops"]),
            "bytes": float(c["bytes"]),
            # Bytes moved by gather/scatter element access: on TPU these
            # serialise (no vector gather hardware — DESIGN.md §2) and
            # run at a fraction of stream bandwidth; consumers derate
            # them (GATHER_DERATE in repro.analysis.hlo).
            "gather_bytes": float(c["gather_bytes"]),
            "collectives": {k.replace("coll_", ""): v
                            for k, v in coll.items()},
            "census": census,
        }


    # ------------------------------------------------------------------
    def multipliers(self) -> dict[str, int]:
        """Loop-trip multiplier per computation (reachable from entry)."""
        mult = {self.entry: 1}
        stack = [self.entry]
        while stack:
            name = stack.pop()
            comp = self.comps.get(name)
            if comp is None:
                continue
            for ins in comp.instrs:
                subs = _CALLED.findall(ins.tail)
                if ins.opcode == "while":
                    m = _TRIP.search(ins.tail)
                    trip = int(m.group(1)) if m else 1
                else:
                    trip = 1
                for sub in subs:
                    if sub in self.comps:
                        add = mult[name] * trip
                        if mult.get(sub, 0) < add:
                            mult[sub] = add
                            stack.append(sub)
        return mult

    def top_instructions(self, kinds=None, n=15):
        """Largest loop-weighted contributors: (weighted_bytes, opcode,
        raw_bytes, multiplier, computation, instr-name)."""
        mult = self.multipliers()
        rows = []
        for cname, m in mult.items():
            comp = self.comps[cname]
            for ins in comp.instrs:
                base = ins.opcode.removesuffix("-start")
                if kinds and base not in kinds:
                    continue
                b, _ = _bytes_elems(ins.out_shapes)
                w = b * (2 if base == "all-reduce" else 1) * m
                rows.append((w, base, b, m, cname, ins.name))
        rows.sort(reverse=True)
        return rows[:n]


def analyze_module(hlo_text: str) -> dict:
    """Loop-weighted per-device analysis of one optimised HLO module."""
    return HloModule(hlo_text).analyze()
