"""HLO text analysis: collective bytes, op census, roofline terms.

This is the dry-run "profiler" (there is no hardware): everything §Roofline
needs is derived from ``lowered.compile()`` artifacts —

* ``cost_analysis()``      -> per-device HLO flops + bytes accessed
* ``memory_analysis()``    -> per-device argument/temp/peak bytes
* ``as_text()``            -> collective ops, parsed here into bytes moved

and the paper-methodology op census (Table 2 analogue): classify every HLO
op into memory / shuffle / arithmetic / gather / other, exactly like the
paper classifies x86 instructions.

Hardware constants are TPU v5e per chip: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (3D-torus link).
"""

from __future__ import annotations

import re
from collections import Counter

__all__ = ["parse_shape_bytes", "collective_bytes", "op_census",
           "roofline_terms", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
# Effective-bandwidth derate for gather/scatter element access: TPU has
# no vector-gather hardware (DESIGN.md §2); XLA:TPU lowers row gathers to
# serialised dynamic-slices, sustaining roughly 1/16 of stream bandwidth
# for 4-byte elements (one element per 64B+ transaction).  This plays the
# role of the paper's measured Table-4 gather latencies in the TPU model.
GATHER_DERATE = 16.0

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# Paper Table-2 instruction classes mapped to HLO opcodes.
_CLASS = {
    "memory": {"copy", "dynamic-slice", "dynamic-update-slice", "slice",
               "concatenate", "pad", "parameter", "constant", "iota",
               "broadcast"},
    "gather": {"gather", "scatter"},
    "shuffle": {"transpose", "reshape", "bitcast", "reverse", "select"},
    "arith": {"add", "subtract", "multiply", "divide", "dot", "fusion",
              "exponential", "log", "rsqrt", "sqrt", "maximum", "minimum",
              "compare", "convert", "negate", "power", "tanh", "floor",
              "and", "or", "xor", "reduce", "convolution"},
}


def parse_shape_bytes(typestr: str) -> int:
    """Total bytes of every shape literal in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _instr_lines(hlo_text: str):
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" in s and not s.startswith(("HloModule", "ENTRY", "}", "%")):
            yield s
        elif s.startswith("%") and "=" in s:
            yield s


def collective_bytes(hlo_text: str) -> dict:
    """Bytes moved per device by collectives, summed from the HLO.

    Convention: per op we count the *output* shape bytes, doubled for
    all-reduce (ring = reduce-scatter + all-gather).  ``start`` variants
    (async collectives) are counted once; ``done`` ops are skipped.
    Returns ``{op_kind: bytes, ..., "total": bytes}``.
    """
    out: Counter = Counter()
    for line in _instr_lines(hlo_text):
        lhs, _, rhs = line.partition("=")
        rhs = rhs.strip()
        m = re.match(r"(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
                     r"([a-z0-9-]+)", rhs)
        if not m:
            continue
        op = m.group(1)
        base = op.removesuffix("-start")
        if base not in COLLECTIVES or op.endswith("-done"):
            continue
        typestr = rhs[:m.start(1)]
        nbytes = parse_shape_bytes(typestr)
        if base == "all-reduce":
            nbytes *= 2
        out[base] += nbytes
    out["total"] = sum(v for k, v in out.items())
    return dict(out)


def op_census(hlo_text: str) -> dict:
    """Classify HLO ops paper-style: memory/shuffle/arith/gather/other.

    Counts *instruction instances* in the optimised module (fusions count
    once — like one x86 instruction retiring a pipeline of uops).
    """
    census: Counter = Counter()
    ops: Counter = Counter()
    for line in _instr_lines(hlo_text):
        rhs = line.partition("=")[2].strip()
        m = re.match(r"(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
                     r"([a-z0-9-]+)", rhs)
        if not m:
            continue
        op = m.group(1).removesuffix("-start").removesuffix("-done")
        ops[op] += 1
        for cls, names in _CLASS.items():
            if op in names:
                census[cls] += 1
                break
        else:
            census["other"] += 1
    census["total"] = sum(ops.values())
    return {"classes": dict(census), "ops": dict(ops)}


def roofline_terms(flops_dev: float, bytes_dev: float,
                   coll_bytes_dev: float) -> dict:
    """The three §Roofline terms, in seconds per step per device."""
    compute = flops_dev / PEAK_FLOPS
    memory = bytes_dev / HBM_BW
    collective = coll_bytes_dev / ICI_BW
    dominant = max(
        (("compute", compute), ("memory", memory),
         ("collective", collective)), key=lambda kv: kv[1])
    total = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant[0],
        "bound_s": total,
    }
