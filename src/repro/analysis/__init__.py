"""Compiled-artifact analysis: HLO op census, collectives, roofline."""

from .hlo import (  # noqa: F401
    collective_bytes,
    op_census,
    parse_shape_bytes,
    roofline_terms,
)
