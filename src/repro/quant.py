"""Shared int8 error-feedback quantisation (DESIGN.md §12).

Two consumers move int8 codes instead of f32 values and need the *same*
quantise-with-residual primitive:

* :func:`repro.dist.collectives.compress_psum` — gradient-style
  all-reduce compression on a symmetric per-leaf grid, residual carried
  *across calls* so the running mean converges;
* the ``strip_dtype="int8"`` wire — the padded detector image encoded
  once at pad time into int8 codes plus per-detector-row f32
  scale/zero-point, residual carried *along each row* so quantisation
  error is redistributed within the row instead of accumulating along
  it (classic sigma-delta error diffusion).

:func:`quantize_ef` is that primitive, factored out of the idiom
``compress_psum`` shipped first.  The row-wire layer on top
(:func:`quantize_rows` / :func:`dequantize_rows`) owns the per-row
affine grid: ``value = code * scale[row] + offset[row]`` with codes in
``[-127, 127]``.  The grid always contains 0 exactly representable to
within half a step (the row range is widened to include 0), and an
all-zero row — the zero-padded border every strip sampler relies on —
decodes to *exactly* 0.0: its codes are all ``-127`` and its offset is
``-(-127) * scale`` by construction, so the two products cancel
bitwise.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["RowQuant", "quantize_ef", "quantize_rows", "dequantize_rows"]

# Smallest scale a degenerate (constant) range quantises at — keeps the
# divide finite; chosen so ``127 * _EPS_SCALE`` is still a normal f32.
_EPS_SCALE = 1e-30


def quantize_ef(x, scale, offset=None, *, error=None):
    """One error-feedback quantisation step onto an int8 grid.

    Quantises ``x`` (plus the carried residual ``error``) to codes in
    ``[-127, 127]`` on the grid ``code * scale (+ offset)`` and returns
    ``(codes, new_error)`` where ``new_error = (x + error) -
    dequant(codes)`` — the residual the caller feeds into the *next*
    step (the EF trick that turns a biased one-shot compressor into an
    asymptotically exact stream).  ``offset=None`` selects the
    symmetric grid (no add on either side — the exact
    ``compress_psum`` arithmetic); ``error=None`` starts a fresh
    residual chain.  Codes are returned as f32 (callers cast to int8
    for the wire; the residual math needs the f32 value anyway).
    """
    xp = x if error is None else x + error
    centred = xp if offset is None else xp - offset
    q = jnp.clip(jnp.round(centred / scale), -127.0, 127.0)
    deq = q * scale if offset is None else q * scale + offset
    return q, xp - deq


class RowQuant(NamedTuple):
    """Per-row affine int8 encoding of a 2-D image (a jax pytree).

    ``value[r, c] = codes[r, c] * scale[r] + offset[r]`` — one f32
    scale/zero-point pair per detector row, 8 bytes of sideband per row
    against 1 byte/pixel on the wire.
    """

    codes: jnp.ndarray          # int8 (rows, cols)
    scale: jnp.ndarray          # f32 (rows,)
    offset: jnp.ndarray         # f32 (rows,)


def _row_grid(x, symmetric: bool):
    """Per-row ``(scale, offset)`` of the affine (or symmetric) grid.

    The row range is widened to include 0 — out-of-detector taps must
    decode to ~0, so 0 has to sit on every row's grid within half a
    step regardless of the row's own values.
    """
    if symmetric:
        amax = jnp.max(jnp.abs(x), axis=1)
        scale = jnp.maximum(amax, _EPS_SCALE) / 127.0
        return scale, jnp.zeros_like(scale)
    lo = jnp.minimum(jnp.min(x, axis=1), 0.0)
    hi = jnp.maximum(jnp.max(x, axis=1), 0.0)
    scale = jnp.maximum(hi - lo, _EPS_SCALE) / 254.0
    # Code -127 decodes to ``lo`` exactly: offset = lo + 127 * scale.
    # For an all-zero row lo = hi = 0, so offset = 127 * scale and the
    # (all -127) codes decode to -127*scale + 127*scale == 0.0 bitwise.
    return scale, lo + 127.0 * scale


def quantize_rows(image, *, symmetric: bool = False) -> RowQuant:
    """Encode a 2-D f32 image into per-row affine int8 codes.

    The residual feedback runs *along each row* (a ``lax.scan`` over
    columns whose carry is one residual per row): each column's
    quantisation error is added to the next column before it quantises,
    so the error is redistributed within the row — the running sum of
    per-pixel errors along any row prefix stays bounded by ~one grid
    step instead of growing with the row length.  Rows are independent;
    nothing leaks across them.  ``symmetric=True`` forces a zero
    offset (the ``compress_psum`` grid, per row).
    """
    x = jnp.asarray(image, jnp.float32)
    if x.ndim != 2:
        raise ValueError(
            f"quantize_rows wants a 2-D image, got shape {x.shape}")
    scale, offset = _row_grid(x, symmetric)

    def step(err, col):             # err, col: (rows,) — one scan per col
        q, err = quantize_ef(col, scale, offset, error=err)
        return err, q

    _, codes_t = jax.lax.scan(step, jnp.zeros_like(scale), x.T)
    return RowQuant(codes_t.T.astype(jnp.int8), scale, offset)


def dequantize_rows(rq: RowQuant):
    """Decode per-row affine int8 codes back to f32."""
    return (rq.codes.astype(jnp.float32) * rq.scale[:, None]
            + rq.offset[:, None])
