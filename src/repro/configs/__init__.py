"""Architecture registry: --arch <id> resolves here."""

from .base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401
from .registry import ARCHS, get_arch  # noqa: F401
