"""chatglm3-6b — RoPE 2d, GQA kv=2 [arXiv:2406.12793; hf].

28L d_model=4096 32H (kv=2) d_ff=13696 vocab=65024.  2D RoPE: rotary on
half the head dim, pass-through on the rest; qkv bias on.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    rope="rope2d",
    qkv_bias=True,
)
