"""Architecture registry: maps ``--arch`` ids to configs.

Also owns the per-arch shape applicability rules from the assignment:
``long_500k`` needs sub-quadratic sequence mixing, so it only runs for
the SSM/hybrid archs (skips recorded, not silently dropped).
"""

from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeConfig
from .chatglm3_6b import CONFIG as chatglm3_6b
from .internlm2_20b import CONFIG as internlm2_20b
from .jamba_v01_52b import CONFIG as jamba_v01_52b
from .kimi_k2_1t_a32b import CONFIG as kimi_k2_1t_a32b
from .mistral_nemo_12b import CONFIG as mistral_nemo_12b
from .nemotron_4_15b import CONFIG as nemotron_4_15b
from .qwen2_vl_2b import CONFIG as qwen2_vl_2b
from .qwen3_moe_235b_a22b import CONFIG as qwen3_moe_235b_a22b
from .whisper_small import CONFIG as whisper_small
from .xlstm_125m import CONFIG as xlstm_125m

__all__ = ["ARCHS", "get_arch", "cells", "cell_supported"]

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        xlstm_125m, jamba_v01_52b, chatglm3_6b, internlm2_20b,
        mistral_nemo_12b, nemotron_4_15b, qwen3_moe_235b_a22b,
        kimi_k2_1t_a32b, qwen2_vl_2b, whisper_small,
    )
}

# Families whose sequence mixing is sub-quadratic end-to-end.
_SUBQUADRATIC = {"ssm", "hybrid"}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(supported, reason-if-not) for one (arch x shape) cell."""
    if shape.name == "long_500k" and cfg.family not in _SUBQUADRATIC:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is full-attention ({cfg.family}) — "
                       "skip per assignment, DESIGN.md §6")
    return True, ""


def cells():
    """All 40 (arch, shape) cells with support flags."""
    out = []
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            ok, why = cell_supported(cfg, shape)
            out.append((cfg, shape, ok, why))
    return out
