"""jamba-v0.1-52b — Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887; hf].

32L d_model=4096 32H (kv=8) d_ff=14336 vocab=65536, MoE 16 experts top-2
every other layer.  Period of 8 blocks: one attention + seven mamba; no
positional encoding (the mamba blocks carry position).  Hybrid -> runs
``long_500k``.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    moe=True,
    n_experts=16,
    top_k=2,
    moe_d_ff=14336,
    moe_every=2,
    rope="nope",
    d_state=16,
    d_conv=4,
    ssm_expand=2,
)
