"""qwen2-vl-2b — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=1536 12H (kv=2) d_ff=8960 vocab=151936.  Backbone only: the
ViT frontend is a stub linear adapter over precomputed patch features
(``input_specs`` supplies them); M-RoPE positions use a (t, h, w) grid
for the patch prefix and degenerate to standard RoPE for text.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    rope="mrope",
    frontend="vision",
    qkv_bias=True,
)
