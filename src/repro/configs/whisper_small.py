"""whisper-small — enc-dec, conv frontend (stub) [arXiv:2212.04356].

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865.  Encoder-decoder: 12
encoder + 12 decoder layers with cross-attention; the conv/mel frontend
is a stub linear adapter over precomputed 80-dim frames.  Sinusoidal
positions (no RoPE), LayerNorm, GELU, tied embeddings.  Full attention
-> ``long_500k`` skipped (DESIGN.md §6).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    enc_dec=True,
    n_enc_layers=12,
    frontend="audio",
    rope="none",
    norm="layernorm",
    mlp_act="gelu",
    tie_embeddings=True,
)
