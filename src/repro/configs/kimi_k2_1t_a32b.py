"""kimi-k2-1t-a32b — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (kv=8) vocab=163840, MoE 384e top-8 with expert
d_ff=2048 on every layer.  The heaviest dry-run cell: ~1T params; fitting
512 v5e chips requires FSDP across pods + 8-bit optimizer state
(EXPERIMENTS.md §Dry-run).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=0,
    vocab=163840,
    moe=True,
    n_experts=384,
    top_k=8,
    moe_d_ff=2048,
    moe_every=1,
    rope_theta=5e4,
)
