"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H (kv=4) d_ff=0 vocab=50304.  No separate FFN: xLSTM
blocks carry their own up-projection (d_ff=0 in the assignment).  The
block pattern alternates mLSTM/sLSTM; both are streaming recurrences, so
this arch runs the ``long_500k`` cell (O(1) decode state).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm", "slstm"),
    rope="nope",
    norm="layernorm",
    ssm_expand=2,
    tie_embeddings=True,
)
