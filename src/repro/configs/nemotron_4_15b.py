"""nemotron-4-15b — GQA, squared-ReLU [arXiv:2402.16819; unverified].

32L d_model=6144 48H (kv=8) d_ff=24576 vocab=256000.  Squared-ReLU MLP
(two matrices, no gate), LayerNorm.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    mlp_act="relu2",
    norm="layernorm",
)
