"""Model/run configuration schema for the architecture zoo.

One frozen dataclass describes every assigned architecture (dense, MoE,
SSM, hybrid, enc-dec, VLM backbone).  Architectures are registered by id
(``repro.configs.registry``) and selected with ``--arch <id>`` by every
launcher.  ``reduced()`` derives the CPU-smoke-test configuration — same
family and block pattern, tiny dimensions.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int | None = None          # default d_model // n_heads
    # --- block layout -------------------------------------------------
    # One "period" of blocks, scanned n_layers/len(pattern) times.
    # Entries: "attn" | "mamba" | "mlstm" | "slstm".
    block_pattern: tuple[str, ...] = ("attn",)
    # --- MoE ------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1           # MoE replaces the MLP every k-th block
    capacity_factor: float = 1.25
    # --- attention ------------------------------------------------------
    rope: str = "standard"       # standard | rope2d | mrope | none
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_chunk: int = 1024       # online-softmax KV block (0 = dense)
    kv_cache_dtype: str = "bf16"  # bf16 | int8 (quantised decode cache)
    # --- mlp / norm -------------------------------------------------
    mlp_act: str = "swiglu"      # swiglu | gelu | relu2
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    # --- ssm ------------------------------------------------------------
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2
    # --- enc-dec / frontends ---------------------------------------
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str | None = None  # None | "audio" | "vision"
    # --- the paper's technique (first-class switch) -----------------
    gather_impl: str = "take"    # take | onehot | auto
    # --- numerics ---------------------------------------------------
    param_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, \
            f"{self.name}: n_layers={self.n_layers} % period={self.period}"
        return self.n_layers // self.period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def moe_at(self, block_idx: int) -> bool:
        return self.moe and (block_idx % self.moe_every == self.moe_every - 1)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, hd = self.d_model, self.hd
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.block_pattern[i % self.period]
            if kind == "attn":
                n += d * (self.n_heads * hd) * 2            # wq, wo
                n += d * (self.n_kv_heads * hd) * 2         # wk, wv
            elif kind == "mamba":
                di = self.d_inner
                n += d * 2 * di + di * d                    # in/out proj
                n += di * (self.d_state * 2 + 2) + di * self.d_conv
            elif kind in ("mlstm", "slstm"):
                di = self.d_inner
                n += d * di * 4 + di * d
            if self.moe_at(i):
                n += d * self.n_experts                     # router
                n += self.n_experts * 3 * d * self.moe_d_ff
            elif self.d_ff:
                n += 3 * d * self.d_ff if self.mlp_act == "swiglu" \
                    else 2 * d * self.d_ff
            n += 2 * d                                      # norms
        if self.enc_dec:
            # encoder self-attn + mlp + decoder cross-attn, rough
            n += self.n_enc_layers * (4 * d * self.n_heads * hd
                                      + 2 * d * self.d_ff + 2 * d)
            n += self.n_layers * 4 * d * self.n_heads * hd  # cross attn
        return n

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        moe_blocks = sum(self.moe_at(i) for i in range(self.n_layers))
        expert_params = moe_blocks * self.n_experts * 3 * self.d_model \
            * self.moe_d_ff
        active = moe_blocks * self.top_k * 3 * self.d_model * self.moe_d_ff
        return full - expert_params + active

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 * len(self.block_pattern),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.moe else 0,
            top_k=min(self.top_k, 2) if self.moe else 0,
            moe_d_ff=32 if self.moe else 0,
            d_state=8,
            ssm_expand=2,
            attn_chunk=0,
            n_enc_layers=2 if self.enc_dec else 0,
            param_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
