"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (kv=4) vocab=151936, MoE 128e top-8 with expert
d_ff=1536 on every layer (no dense MLP); head_dim=128.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab=151936,
    moe=True,
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    moe_every=1,
    rope_theta=1e6,
)
