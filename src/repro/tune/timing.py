"""Wall-clock timing of jitted callables (shared by tune + benchmarks).

One implementation serves both the benchmark harness (``benchmarks/common``
re-exports it) and the autotuner sweep driver, so a tuned decision and a
benchmark row are always comparable numbers.
"""

from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["time_fn"]


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, **kw):
    """Median wall time (seconds) of jitted ``fn``; blocks on results."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))
