"""Wall-clock timing of jitted callables (shared by tune + benchmarks).

One implementation serves both the benchmark harness (``benchmarks/common``
re-exports it) and the autotuner sweep driver, so a tuned decision and a
benchmark row are always comparable numbers.

The iteration count adapts to a minimum *total* measured time: a fixed
``iters=5`` made µs-scale medians (tiny CPU shapes in BENCH_ct.json)
timer-noise lotteries, while second-scale problems were already stable at
a handful of iterations.  ``iters`` is the floor, ``min_total_s`` the
target the loop keeps sampling toward, ``max_iters`` the runaway bound.
"""

from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["time_fn"]


def time_fn(fn, *args, warmup: int = 2, iters: int = 5,
            min_total_s: float = 0.05, max_iters: int = 1000, **kw):
    """Median wall time (seconds) of jitted ``fn``; blocks on results.

    Runs at least ``iters`` timed calls, then keeps sampling until the
    accumulated measurement time reaches ``min_total_s`` (or
    ``max_iters`` calls), so fast calls get enough samples for a stable
    median and slow calls pay no extra iterations.  ``min_total_s=0``
    restores the fixed-count behaviour.
    """
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    total = 0.0
    while len(times) < iters or (total < min_total_s
                                 and len(times) < max_iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        times.append(dt)
        total += dt
    return float(np.median(times))
