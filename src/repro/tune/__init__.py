"""Strategy autotuning: sweep, cache, and ``strategy="auto"`` resolution.

The paper's contribution is an empirical comparison of gather schemes per
chip; this package makes that comparison executable and its outcome
persistent.  See DESIGN.md §6.
"""

from .cache import (DEFAULT_STRATEGY, TUNE_SCHEMA_VERSION, TunedConfig,
                    autotune, cache_key,
                    clear_memory_cache, device_identity,
                    filter_strategy_opts, load_tuned,
                    resolve_pallas_config, resolve_strategy, store_tuned,
                    tune_dir)
from .space import (Candidate, default_space, jnp_candidates,
                    pallas_batch_fits_vmem, pallas_candidates)
from .sweep import SweepResult, Timing, sweep_strategies
from .timing import time_fn

__all__ = [
    "DEFAULT_STRATEGY", "TUNE_SCHEMA_VERSION", "TunedConfig", "autotune", "cache_key",
    "clear_memory_cache", "device_identity", "filter_strategy_opts",
    "load_tuned",
    "resolve_pallas_config", "resolve_strategy", "store_tuned", "tune_dir",
    "Candidate", "default_space", "jnp_candidates",
    "pallas_batch_fits_vmem", "pallas_candidates",
    "SweepResult", "Timing", "sweep_strategies", "time_fn",
]
