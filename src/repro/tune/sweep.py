"""Sweep driver: time every candidate strategy/config on this backend.

The measurement mirrors ``benchmarks/fig1_single_device`` (projections
into an ``L^3`` volume, median of a few runs via :func:`timing.time_fn`)
so tuned decisions and benchmark rows are directly comparable.  A
candidate carrying ``pbatch`` is timed through the batch-major drivers on
a ``pbatch``-deep projection stack and normalised to **us per
projection**, so depths compete on one scale with the classical
per-projection nest.  Candidates whose static windows cannot cover the
geometry's tap footprint are *skipped with a recorded reason* rather than
timed — a config the validator rejects would produce silently wrong
voxels, and a tuner must never select one.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backproject import (STRATEGIES, GeomStatic,
                                    backproject_batch, backproject_one,
                                    validate_strip_opts)
from repro.core.geometry import Geometry, projection_matrices, \
    projection_matrix

from .cache import device_identity
from .space import Candidate, default_space
from .timing import time_fn

__all__ = ["Timing", "SweepResult", "sweep_strategies"]


@dataclasses.dataclass(frozen=True)
class Timing:
    """One measured sweep point (``us_per_call`` = us per *projection*)."""

    label: str
    strategy: str
    opts: tuple
    us_per_call: float
    gups: float                     # billions of voxel updates / second

    def as_dict(self) -> dict:
        return {"label": self.label, "strategy": self.strategy,
                "opts": dict(self.opts), "us_per_call": self.us_per_call,
                "gups": self.gups}


@dataclasses.dataclass
class SweepResult:
    geom_key: tuple
    backend: str
    device_kind: str
    timings: list[Timing]
    skipped: list[tuple[str, str]]  # (candidate label, reason)

    def best(self, strategies: tuple[str, ...] | None = None):
        pool = [t for t in self.timings
                if strategies is None or t.strategy in strategies]
        return min(pool, key=lambda t: t.us_per_call) if pool else None


def _default_problem(geom: Geometry):
    """One mid-sweep projection of white noise (access-pattern-faithful;
    the timings do not depend on image content)."""
    rng = np.random.default_rng(0)
    image = jnp.asarray(rng.standard_normal((geom.n_v, geom.n_u)),
                        jnp.float32)
    theta = float(geom.angles[geom.n_proj // 2])
    A = jnp.asarray(projection_matrix(geom, theta), jnp.float32)
    return image, A


def _batch_problem(geom: Geometry, image, pbatch: int):
    """A ``pbatch``-deep stack around the mid-sweep angle: distinct
    matrices (faithful strip-origin churn), one noise image replicated."""
    k0 = max(0, geom.n_proj // 2 - pbatch // 2)
    thetas = [float(geom.angles[min(k0 + i, geom.n_proj - 1)])
              for i in range(pbatch)]
    mats = jnp.asarray(np.stack([projection_matrix(geom, th)
                                 for th in thetas]), jnp.float32)
    images = jnp.broadcast_to(image, (pbatch,) + image.shape)
    return images, mats


def sweep_strategies(geom: Geometry, *, image=None, A=None,
                     space: list[Candidate] | None = None,
                     include_pallas: bool | None = None,
                     warmup: int = 1, iters: int = 3,
                     min_total_s: float | None = None) -> SweepResult:
    """Time every valid candidate for ``geom`` on the current backend.

    ``include_pallas=None`` auto-selects: the kernel is timed only where
    it compiles (TPU) — interpreter-mode timings would be meaningless.
    ``min_total_s`` overrides :func:`time_fn`'s adaptive floor (pass 0
    to pin the sample count to ``iters`` exactly — cheap smoke sweeps).
    """
    tkw = {} if min_total_s is None else {"min_total_s": min_total_s}
    gs = GeomStatic.of(geom)
    backend = jax.default_backend()
    if include_pallas is None:
        include_pallas = backend == "tpu"
    if space is None:
        space = default_space(gs, include_pallas=include_pallas)
    if image is None or A is None:
        image, A = _default_problem(geom)
    # A decision is persisted for the *geometry*, so candidate windows
    # must cover the footprint at every projection angle — the timing
    # matrix alone could admit a config that loses taps (or fails
    # validation) at the sweep extremes once reconstruct() runs the
    # full set.
    mats_all = np.asarray(projection_matrices(geom), np.float64)
    vol0 = jnp.zeros((gs.L,) * 3, jnp.float32)

    timings: list[Timing] = []
    skipped: list[tuple[str, str]] = []
    for cand in space:
        opts = dict(cand.opts)
        pbatch = max(1, int(opts.pop("pbatch", 1)))
        try:
            if cand.strategy in STRATEGIES:
                validate_strip_opts(geom, mats_all, cand.strategy, opts)
                if pbatch == 1:
                    t = time_fn(backproject_one, vol0, image, A, geom,
                                strategy=cand.strategy, warmup=warmup,
                                iters=iters, **tkw, **opts)
                else:
                    images, mats = _batch_problem(geom, image, pbatch)
                    t = time_fn(backproject_batch, vol0, images, mats,
                                geom, strategy=cand.strategy,
                                pbatch=pbatch, warmup=warmup,
                                iters=iters, **tkw, **opts) / pbatch
            elif cand.strategy == "pallas":
                from repro.kernels.backproject_ops import (
                    clamp_tiles, pallas_backproject_batch,
                    pallas_backproject_one, shared_window_dims,
                    validate_strip_config)
                from .space import pallas_batch_fits_vmem
                ty, chunk, band, width = clamp_tiles(
                    gs, opts.get("ty", 8), opts.get("chunk", 128),
                    opts.get("band", 16), opts.get("width", 512))
                if opts.get("shared_window", False):
                    # Size the superset window over the *full* matrix
                    # set (what reconstruct-time resolution will see)
                    # and screen it against the VMEM budget — the
                    # planner-tight dims can exceed the base strip's.
                    pb_eff = max(1, min(pbatch, geom.n_proj))
                    sband, swidth = shared_window_dims(
                        geom, mats_all, ty=ty, chunk=chunk,
                        pbatch=pb_eff,
                        shared_band=opts.get("shared_band"),
                        shared_width=opts.get("shared_width"))
                    itemsize = {"bfloat16": 2, "int8": 1}.get(
                        str(opts.get("strip_dtype")), 4)
                    if not pallas_batch_fits_vmem(
                            gs, pbatch=pb_eff, ty=ty, chunk=chunk,
                            band=sband, width=swidth, depth=pb_eff,
                            itemsize=itemsize):
                        raise ValueError(
                            f"shared window ({sband}, {swidth}) x "
                            f"pbatch={pb_eff} exceeds the VMEM budget")
                else:
                    for A_i in mats_all:
                        # Micro candidates validate at *their* window
                        # values — the same values the candidate
                        # persists, so the resolved config always ran
                        # through this check.
                        validate_strip_config(
                            geom, A_i, ty=ty, chunk=chunk, band=band,
                            width=width,
                            micro=bool(opts.get("micro", False)),
                            micro_group=int(opts.get("micro_group", 8)),
                            micro_band=int(opts.get("micro_band", 8)),
                            micro_width=int(opts.get("micro_width", 32)))
                if pbatch == 1:
                    t = time_fn(pallas_backproject_one, vol0, image, A,
                                geom, warmup=warmup, iters=iters, **tkw,
                                **opts)
                else:
                    images, mats = _batch_problem(geom, image, pbatch)
                    t = time_fn(pallas_backproject_batch, vol0, images,
                                mats, geom, pbatch=pbatch, validate=False,
                                warmup=warmup, iters=iters, **tkw,
                                **opts) / pbatch
            else:
                raise ValueError(f"unknown candidate strategy "
                                 f"{cand.strategy!r}")
        except ValueError as e:
            skipped.append((cand.label, str(e)))
            continue
        timings.append(Timing(
            label=cand.label, strategy=cand.strategy, opts=cand.opts,
            us_per_call=t * 1e6, gups=gs.L ** 3 / t / 1e9))

    backend, device_kind = device_identity(backend)
    return SweepResult(geom_key=tuple(gs), backend=backend,
                       device_kind=device_kind,
                       timings=timings, skipped=skipped)
