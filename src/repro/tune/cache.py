"""TunedConfig cache: per-(geometry, backend, device) strategy decisions.

A tuned decision is keyed on ``(GeomStatic, backend, device_kind)`` — the
paper's finding restated as a cache key: the winning gather scheme is a
property of the *chip*, not of the algorithm, so decisions made on one
device kind must never leak to another.  Decisions persist as one JSON
file per key under ``.repro_tune/`` (override with ``REPRO_TUNE_DIR``) so
a sweep paid once amortises across processes; an in-process dict
memoises hits.

``strategy="auto"`` consumers call :func:`resolve_strategy` (jnp paths)
or :func:`resolve_pallas_config` (kernel path); both fall back to the
current hard-coded defaults when the key was never tuned, so ``auto`` is
always safe to request.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
from pathlib import Path

import jax

from repro.core.backproject import STRATEGIES, GeomStatic

__all__ = ["TunedConfig", "DEFAULT_STRATEGY", "TUNE_SCHEMA_VERSION",
           "tune_dir", "cache_key",
           "store_tuned", "load_tuned", "clear_memory_cache",
           "device_identity", "filter_strategy_opts", "resolve_strategy",
           "resolve_pallas_config", "autotune"]

# What "auto" means before anyone has tuned: the repo's historical
# hard-coded default.
DEFAULT_STRATEGY = "strip2"

# Bumped whenever the persisted TunedConfig layout or the semantics of a
# tuned decision change (v2: the ``pbatch`` axis — a v1 decision timed
# the per-projection loop nest, which no longer exists; v3: batched
# kernel candidates carry ``double_buffer``/``db_depth``/``micro`` and
# the batch path *honors* them — a v2 decision's variant flags were
# timed against a batch path that silently shed them, so replaying one
# would misattribute its numbers; v4: the ``strip_dtype`` and
# ``shared_window`` axes — a v3 decision predates the bf16-wire and
# superset-window variants, so its "best" never competed against them
# and replaying it would freeze the old design space; v5: the
# ``strip_dtype="int8"`` axis — a v4 decision's wire-dtype winner never
# competed against the per-row-affine int8 candidates, and the VMEM
# screen is now itemsize-aware at 1 byte).  ``load_tuned`` treats any
# other version as untuned, so stale ``.repro_tune/`` files are
# *ignored*, never misread into the new dataclass.
TUNE_SCHEMA_VERSION = 5

# ``micro_*`` ride along with ``micro``: a tuned micro decision was
# validated (and timed) at a specific ``(micro_band, micro_width)``
# window — resolving the flag without the window would run the kernel at
# defaults it was never validated at.  ``db_depth`` likewise rides with
# ``double_buffer``: the depth is part of the timed pipeline shape, and
# ``shared_band``/``shared_width`` with ``shared_window`` (``None`` dims
# auto-size from the group planner at resolve time, so they are usually
# absent).  ``strip_dtype`` is the wire dtype the decision was timed at.
_PALLAS_KEYS = ("ty", "chunk", "band", "width", "double_buffer",
                "db_depth", "micro", "micro_group", "micro_band",
                "micro_width", "shared_window", "shared_band",
                "shared_width", "strip_dtype", "pbatch")

# Options each jnp strategy actually accepts — caller options riding
# along with strategy="auto" are filtered to the *resolved* strategy, so
# a strip2-flavoured option can never reach e.g. sample_onehot(**opts).
# ``pbatch`` is strategy-independent (the batch-major loop nest wraps
# every strategy); ``reconstruct``/``sharded_reconstruct`` pop it before
# options reach any ``sample_*``.
_STRATEGY_KEYS = {
    "scalar": ("pbatch",),
    "gather": ("pbatch",),
    "onehot": ("vox_block", "pbatch"),
    "strip": ("chunk", "band", "width", "strips_per_block", "strip_dtype",
              "pbatch"),
    "strip2": ("group", "gband", "gwidth", "groups_per_block",
               "strip_dtype", "pbatch"),
}

# Every option name *some* jnp strategy accepts.  A caller key outside
# this set is a typo (or an option from a different universe, e.g. a
# Pallas tile key) and raises; a key inside it that the resolved
# strategy does not accept is shed with a warning.
KNOWN_OPTION_KEYS = frozenset(
    k for keys in _STRATEGY_KEYS.values() for k in keys)


def filter_strategy_opts(strategy: str, opts: dict | None, *,
                         strict: bool = False,
                         context: str = "resolve_strategy") -> dict:
    """Filter caller options down to what ``strategy`` accepts — loudly.

    Unknown keys (not accepted by *any* jnp strategy) always raise: a
    typo'd option must never be silently dropped.  Known keys the
    resolved strategy does not accept are shed with a ``RuntimeWarning``
    (``strict=False`` — the ``auto`` path, where the cache may have
    resolved a different strategy than the caller's options were written
    for) or raise (``strict=True`` — an explicitly named strategy, where
    an inapplicable option is a caller bug).
    """
    out, shed = {}, []
    allowed = _STRATEGY_KEYS[strategy]
    for k, v in dict(opts or {}).items():
        if k in allowed:
            out[k] = v
        elif k in KNOWN_OPTION_KEYS:
            shed.append(k)
        else:
            raise ValueError(
                f"{context}: unknown option {k!r} (no jnp strategy "
                f"accepts it); known options: "
                f"{tuple(sorted(KNOWN_OPTION_KEYS))}")
    if shed:
        msg = (f"{context}: option(s) {sorted(shed)} do not apply to "
               f"strategy {strategy!r} (accepts {tuple(allowed)})")
        if strict:
            raise ValueError(msg)
        import warnings

        warnings.warn(msg + "; shedding them", RuntimeWarning,
                      stacklevel=3)
    return out


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One cached decision plus the sweep evidence behind it."""

    strategy: str                   # best jnp strategy (in STRATEGIES)
    opts: dict                      # its tile options (incl. ``pbatch``)
    backend: str
    device_kind: str
    us_per_call: float              # best jnp median time per projection
    pallas: dict | None = None      # best kernel config, when swept
    pallas_us: float | None = None
    timings: list = dataclasses.field(default_factory=list)
    version: int = TUNE_SCHEMA_VERSION

    @property
    def pbatch(self) -> int:
        """Projection batch depth of the winning jnp decision."""
        from repro.core.backproject import DEFAULT_PBATCH

        return int(self.opts.get("pbatch", DEFAULT_PBATCH))

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def tune_dir() -> Path:
    return Path(os.environ.get("REPRO_TUNE_DIR", ".repro_tune"))


def _sanitize(s: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]+", "-", s)


def device_identity(backend: str | None = None,
                    device_kind: str | None = None) -> tuple[str, str]:
    """The ``(backend, device_kind)`` pair cache keys and bench metadata
    are built from — one definition so they can never disagree."""
    if backend is None:
        backend = jax.default_backend()
    if device_kind is None:
        dev = jax.devices()[0]
        device_kind = getattr(dev, "device_kind", str(dev))
    return backend, device_kind


def cache_key(gs: GeomStatic, backend: str, device_kind: str) -> str:
    geom = (f"ct-L{gs.L}-u{gs.n_u}-v{gs.n_v}"
            f"-O{gs.O:g}-MM{gs.MM:g}")
    return f"{geom}--{_sanitize(backend)}--{_sanitize(device_kind)}"


_MEM: dict[tuple[str, str], TunedConfig] = {}


def clear_memory_cache() -> None:
    """Drop in-process memoised decisions (tests; tune-dir swaps)."""
    _MEM.clear()


def store_tuned(gs: GeomStatic, cfg: TunedConfig,
                dirpath: str | os.PathLike | None = None) -> Path:
    d = Path(dirpath) if dirpath is not None else tune_dir()
    d.mkdir(parents=True, exist_ok=True)
    key = cache_key(gs, cfg.backend, cfg.device_kind)
    path = d / f"{key}.json"
    path.write_text(json.dumps(cfg.as_dict(), indent=2, sort_keys=True))
    _MEM[(str(d), key)] = cfg
    return path


def load_tuned(gs: GeomStatic, backend: str | None = None,
               device_kind: str | None = None,
               dirpath: str | os.PathLike | None = None
               ) -> TunedConfig | None:
    backend, device_kind = device_identity(backend, device_kind)
    d = Path(dirpath) if dirpath is not None else tune_dir()
    key = cache_key(gs, backend, device_kind)
    hit = _MEM.get((str(d), key))
    if hit is not None:
        return hit
    path = d / f"{key}.json"
    if not path.is_file():
        return None
    try:
        data = json.loads(path.read_text())
        if (not isinstance(data, dict)
                or data.get("version") != TUNE_SCHEMA_VERSION):
            return None             # stale schema: ignored, not misread
        cfg = TunedConfig(**data)
    except (json.JSONDecodeError, TypeError, ValueError):
        return None                 # corrupt cache file: treat as untuned
    _MEM[(str(d), key)] = cfg
    return cfg


# ----------------------------------------------------------------------
# "auto" resolution
# ----------------------------------------------------------------------

def resolve_strategy(gs: GeomStatic, opts: dict | None = None, *,
                     backend: str | None = None,
                     device_kind: str | None = None,
                     dirpath: str | os.PathLike | None = None
                     ) -> tuple[str, dict]:
    """Map ``strategy="auto"`` to a concrete jnp strategy + options.

    Untuned keys fall back to :data:`DEFAULT_STRATEGY` with the caller's
    options untouched, so ``auto`` reproduces today's default behaviour
    bit-for-bit.  Explicitly passed options override tuned ones per key,
    but only those the resolved strategy accepts survive — the cache may
    have tuned a *different* strategy than the one the caller's options
    were written for.  Shedding is loud (:func:`filter_strategy_opts`):
    unknown keys raise, known-but-inapplicable ones warn.
    """
    cfg = load_tuned(gs, backend, device_kind, dirpath)
    if cfg is None or cfg.strategy not in STRATEGIES:
        strategy, merged = DEFAULT_STRATEGY, {}
    else:
        strategy = cfg.strategy
        # Tuned opts always belong to the tuned strategy; filter them
        # defensively (a hand-edited cache file) but never warn on them.
        allowed = _STRATEGY_KEYS[strategy]
        merged = {k: v for k, v in dict(cfg.opts).items() if k in allowed}
    merged.update(filter_strategy_opts(strategy, opts))
    return strategy, merged


def resolve_pallas_config(gs: GeomStatic, *, backend: str | None = None,
                          device_kind: str | None = None,
                          dirpath: str | os.PathLike | None = None
                          ) -> dict | None:
    """Tuned kernel tile config for this key, or ``None`` when untuned."""
    cfg = load_tuned(gs, backend, device_kind, dirpath)
    if cfg is None or not cfg.pallas:
        return None
    return {k: cfg.pallas[k] for k in _PALLAS_KEYS if k in cfg.pallas}


# ----------------------------------------------------------------------
# End-to-end: sweep this geometry, persist the decision
# ----------------------------------------------------------------------

def autotune(geom, *, image=None, A=None, space=None,
             include_pallas: bool | None = None, warmup: int = 1,
             iters: int = 3, min_total_s: float | None = None,
             dirpath: str | os.PathLike | None = None) -> TunedConfig:
    """Sweep ``geom`` on the current backend and cache the winner."""
    from .sweep import sweep_strategies    # lazy: keeps cache import light

    res = sweep_strategies(geom, image=image, A=A, space=space,
                           include_pallas=include_pallas, warmup=warmup,
                           iters=iters, min_total_s=min_total_s)
    best = res.best(STRATEGIES)
    if best is None:
        raise RuntimeError(
            "autotune swept no valid jnp candidate for this geometry; "
            f"skipped: {res.skipped}")
    best_pallas = res.best(("pallas",))
    cfg = TunedConfig(
        strategy=best.strategy, opts=dict(best.opts),
        backend=res.backend, device_kind=res.device_kind,
        us_per_call=best.us_per_call,
        pallas=dict(best_pallas.opts) if best_pallas else None,
        pallas_us=best_pallas.us_per_call if best_pallas else None,
        timings=[t.as_dict() for t in res.timings])
    store_tuned(GeomStatic.of(geom), cfg, dirpath)
    return cfg
