"""Autotuner search space: candidate (strategy, tile-parameter) points.

The paper's empirical question — which gather/interpolation scheme wins on
a given chip — maps here to a compact grid over the five jnp strategies
(DESIGN.md §2) plus the three Pallas kernel variants, each with the tile
parameters that govern its locality/width trade-off (``chunk``/``band``/
``width`` for strips, ``group``/``gband``/``gwidth`` for micro-windows,
``ty``/``double_buffer``/``micro`` for the kernel).  The space is small by
design: the sweep runs at benchmark time on real hardware, and per
Hofmann et al. the *ordering* shifts per microarchitecture, not the
plausible-region boundaries.
"""

from __future__ import annotations

from typing import NamedTuple

from repro.core.backproject import GeomStatic

__all__ = ["Candidate", "jnp_candidates", "pallas_candidates",
           "default_space"]


class Candidate(NamedTuple):
    """One sweep point: a strategy name plus its static options.

    ``strategy`` is one of ``repro.core.backproject.STRATEGIES`` or
    ``"pallas"``; ``opts`` is a sorted ``(key, value)`` tuple so candidates
    are hashable and stable as cache-file keys.
    """

    strategy: str
    opts: tuple

    @classmethod
    def of(cls, strategy: str, **opts) -> "Candidate":
        return cls(strategy, tuple(sorted(opts.items())))

    @property
    def label(self) -> str:
        if not self.opts:
            return self.strategy
        txt = ",".join(f"{k}={v}" for k, v in self.opts)
        return f"{self.strategy}[{txt}]"


def jnp_candidates(gs: GeomStatic) -> list[Candidate]:
    """Candidate grid for the five jnp strategies, clamped to ``gs``."""
    L = gs.L
    cands = [Candidate.of("scalar"), Candidate.of("gather")]
    for vb in (256, 512):
        cands.append(Candidate.of("onehot", vox_block=min(vb, L * L)))
    for chunk, band, width in ((32, 16, 128), (64, 16, 256)):
        cands.append(Candidate.of(
            "strip", chunk=min(chunk, L), band=min(band, gs.n_v + 2),
            width=min(width, gs.n_u + 2)))
    for group, gband, gwidth in ((8, 8, 64), (8, 8, 32), (16, 8, 128)):
        cands.append(Candidate.of(
            "strip2", group=min(group, L), gband=min(gband, gs.n_v + 2),
            gwidth=min(gwidth, gs.n_u + 2)))
    # De-dup clamped collisions on tiny geometries.
    seen, out = set(), []
    for c in cands:
        if c not in seen:
            seen.add(c)
            out.append(c)
    return out


def pallas_candidates(gs: GeomStatic) -> list[Candidate]:
    """The three kernel variants (plain / double-buffer / micro) at a
    geometry-clamped base tile."""
    base = dict(ty=min(8, gs.L), chunk=min(32, gs.L), band=16, width=128)
    return [
        Candidate.of("pallas", **base),
        Candidate.of("pallas", double_buffer=True, **base),
        Candidate.of("pallas", micro=True, **base),
    ]


def default_space(gs: GeomStatic,
                  include_pallas: bool = True) -> list[Candidate]:
    cands = jnp_candidates(gs)
    if include_pallas:
        cands += pallas_candidates(gs)
    return cands
