"""Autotuner search space: candidate (strategy, tile-parameter) points.

The paper's empirical question — which gather/interpolation scheme wins on
a given chip — maps here to a compact grid over the five jnp strategies
(DESIGN.md §2) plus the Pallas kernel variants, each with the tile
parameters that govern its locality/width trade-off (``chunk``/``band``/
``width`` for strips, ``group``/``gband``/``gwidth`` for micro-windows,
``ty``/``double_buffer``/``micro`` for the kernel).  Every family also
spans the ``pbatch`` axis — how many projections fold into the volume per
volume pass (DESIGN.md §7): the loop-nest inversion trades volume HBM
traffic (÷pbatch) against working-set pressure, so the right depth is a
chip property exactly like the gather scheme.  The space is small by
design: the sweep runs at benchmark time on real hardware, and per
Hofmann et al. the *ordering* shifts per microarchitecture, not the
plausible-region boundaries.
"""

from __future__ import annotations

from typing import NamedTuple

from repro.analysis.lint.budget import (VMEM_BUDGET_BYTES,
                                        batch_vmem_estimate)
from repro.core.backproject import GeomStatic

__all__ = ["Candidate", "jnp_candidates", "pallas_candidates",
           "default_space", "pallas_batch_fits_vmem"]

# Kept as an alias for external readers; the value (and the whole byte
# model) lives in repro.analysis.lint.budget so the tuner's candidate
# screen and the lint budget pass can never drift.
_VMEM_BUDGET_BYTES = VMEM_BUDGET_BYTES

# pbatch depths proposed per candidate family (clamped to n_proj at
# sweep/run time; 1 = the classical per-projection nest).
_PBATCHES = (1, 4)


def pallas_batch_fits_vmem(gs: GeomStatic, *, pbatch: int, ty: int,
                           chunk: int, band: int, width: int,
                           depth: int = 2, itemsize: int = 4) -> bool:
    """Conservative VMEM budget check for a batched kernel candidate.

    Counts every in-flight projection strip at full ``pbatch`` depth or
    the DMA pipeline's ``depth``-slot rotation, whichever is larger
    (the plain batch kernel holds 2 slots, the pipelined variant
    ``db_depth``, and an ANY-space promotion may keep more resident),
    the aliased volume tile pair plus the f32 accumulator, the one-hot
    selector temporaries ``rowsel (ty·chunk, band)`` / ``colsel
    (ty·chunk, width)``, and — for the 1-byte wire — the ``(P, 2,
    rows)`` f32 scale sideband.  A candidate that fails here is never
    proposed — an OOM'd sweep point would abort the whole tune run on
    device.  Delegates to :func:`repro.analysis.lint.budget
    .batch_vmem_estimate`: the lint budget pass and this screen are one
    implementation.
    """
    return batch_vmem_estimate(gs, pbatch=pbatch, ty=ty, chunk=chunk,
                               band=band, width=width, depth=depth,
                               itemsize=itemsize).fits


class Candidate(NamedTuple):
    """One sweep point: a strategy name plus its static options.

    ``strategy`` is one of ``repro.core.backproject.STRATEGIES`` or
    ``"pallas"``; ``opts`` is a sorted ``(key, value)`` tuple so candidates
    are hashable and stable as cache-file keys.  ``opts`` may carry
    ``pbatch`` — the projection batch depth, consumed by the batch-major
    drivers rather than the ``sample_*`` kernels.
    """

    strategy: str
    opts: tuple

    @classmethod
    def of(cls, strategy: str, **opts) -> "Candidate":
        return cls(strategy, tuple(sorted(opts.items())))

    @property
    def label(self) -> str:
        if not self.opts:
            return self.strategy
        txt = ",".join(f"{k}={v}" for k, v in self.opts)
        return f"{self.strategy}[{txt}]"

    @property
    def pbatch(self) -> int:
        return int(dict(self.opts).get("pbatch", 1))


def jnp_candidates(gs: GeomStatic,
                   pbatches: tuple[int, ...] = _PBATCHES
                   ) -> list[Candidate]:
    """Candidate grid for the five jnp strategies, clamped to ``gs``.

    The tile grid is crossed with the ``pbatch`` axis: the batched loop
    nest changes the strategies' memory behaviour (volume traffic ÷
    pbatch, ``pbatch`` detector images hot at once), so the winner must
    be measured per depth, not assumed.
    """
    L = gs.L
    bases = [Candidate.of("scalar"), Candidate.of("gather")]
    for vb in (256, 512):
        bases.append(Candidate.of("onehot", vox_block=min(vb, L * L)))
    for chunk, band, width in ((32, 16, 128), (64, 16, 256)):
        bases.append(Candidate.of(
            "strip", chunk=min(chunk, L), band=min(band, gs.n_v + 2),
            width=min(width, gs.n_u + 2)))
    for group, gband, gwidth in ((8, 8, 64), (8, 8, 32), (16, 8, 128)):
        bases.append(Candidate.of(
            "strip2", group=min(group, L), gband=min(gband, gs.n_v + 2),
            gwidth=min(gwidth, gs.n_u + 2)))
    # The wire-dtype axis on the best strip window: bf16 halves strip
    # bytes at identical tap semantics (f32 accumulate); int8 halves
    # them again via per-row affine codes (repro.quant), paying a
    # one-time encode per projection.  Both must compete.
    bases.append(Candidate.of(
        "strip2", group=min(8, L), gband=min(8, gs.n_v + 2),
        gwidth=min(64, gs.n_u + 2), strip_dtype="bfloat16"))
    bases.append(Candidate.of(
        "strip2", group=min(8, L), gband=min(8, gs.n_v + 2),
        gwidth=min(64, gs.n_u + 2), strip_dtype="int8"))
    cands = [Candidate.of(b.strategy, **dict(b.opts), pbatch=pb)
             for b in bases for pb in pbatches]
    # De-dup clamped collisions on tiny geometries.
    seen, out = set(), []
    for c in cands:
        if c not in seen:
            seen.add(c)
            out.append(c)
    return out


def pallas_candidates(gs: GeomStatic,
                      pbatches: tuple[int, ...] = _PBATCHES
                      ) -> list[Candidate]:
    """Kernel variants at a geometry-clamped base tile: plain /
    double-buffer / micro per-projection, plus the projection-batched
    kernel crossed ``pbatch × {plain, db, micro}`` at every depth that
    fits the VMEM budget — the batch path honors the full tuned config
    surface, so every variant competes at every batch depth.  The
    deepest fitting ``pbatch`` also proposes a 4-deep DMA rotation
    (``db_depth=4``), the ROADMAP's "in-flight depth > 2" point.
    """
    base = dict(ty=min(8, gs.L), chunk=min(32, gs.L), band=16, width=128)
    micro_win = dict(micro=True, micro_group=min(8, gs.L), micro_band=8,
                     micro_width=32)
    cands = [
        Candidate.of("pallas", **base),
        Candidate.of("pallas", double_buffer=True, **base),
        # The micro candidate names its window explicitly so the values
        # it is validated and timed at are the values that persist into
        # the TunedConfig — resolving ``micro=True`` without them would
        # run windows the sweep never saw.  Same for ``db_depth`` with
        # ``double_buffer``.
        Candidate.of("pallas", **micro_win, **base),
    ]
    batched = [pb for pb in pbatches
               if pb > 1 and pallas_batch_fits_vmem(gs, pbatch=pb, **base)]
    for pb in batched:
        cands.append(Candidate.of("pallas", pbatch=pb, **base))
        cands.append(Candidate.of("pallas", pbatch=pb, double_buffer=True,
                                  db_depth=2, **base))
        cands.append(Candidate.of("pallas", pbatch=pb, **micro_win,
                                  **base))
        # Narrow-wire axes on the plain batch kernel: bf16 halves strip
        # DMA bytes, int8 halves them again (per-row affine codes, 1-byte
        # scratch — the VMEM screen at itemsize=1 admits it wherever the
        # f32 config fits).
        cands.append(Candidate.of("pallas", pbatch=pb,
                                  strip_dtype="bfloat16", **base))
        if pallas_batch_fits_vmem(gs, pbatch=pb, itemsize=1, **base):
            cands.append(Candidate.of("pallas", pbatch=pb,
                                      strip_dtype="int8", **base))
        # Shared superset window: one DMA per projection group.  The
        # window dims auto-size from the group planner at run time; the
        # VMEM screen assumes up to 2x the base strip dims per slab
        # (itemsize 2 for the bf16 variant, 1 for int8).
        if pallas_batch_fits_vmem(gs, pbatch=pb, ty=base["ty"],
                                  chunk=base["chunk"],
                                  band=2 * base["band"],
                                  width=2 * base["width"], depth=pb):
            cands.append(Candidate.of("pallas", pbatch=pb,
                                      shared_window=True, **base))
            cands.append(Candidate.of("pallas", pbatch=pb,
                                      shared_window=True,
                                      strip_dtype="bfloat16", **base))
            cands.append(Candidate.of("pallas", pbatch=pb,
                                      shared_window=True,
                                      strip_dtype="int8", **base))
    if batched:
        pb = max(batched)
        if pallas_batch_fits_vmem(gs, pbatch=pb, depth=4, **base):
            cands.append(Candidate.of("pallas", pbatch=pb,
                                      double_buffer=True, db_depth=4,
                                      **base))
    return cands


def default_space(gs: GeomStatic,
                  include_pallas: bool = True) -> list[Candidate]:
    cands = jnp_candidates(gs)
    if include_pallas:
        cands += pallas_candidates(gs)
    return cands
