"""Data pipelines: deterministic synthetic LM tokens + CT projections."""

from .tokens import TokenDataset, make_lm_batches  # noqa: F401
