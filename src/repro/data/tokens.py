"""Deterministic, shardable synthetic LM data pipeline.

Offline container = no real corpora, so the pipeline synthesises a
*learnable* token stream (a mixture of order-2 Markov chains over the
vocabulary) rather than uniform noise — training loss visibly drops,
which is what the end-to-end example and the fault-tolerance tests need
to assert resume-exactness against.

Design points that matter at cluster scale:

* **Stateless addressing**: batch ``i`` of epoch ``e`` is a pure function
  of ``(seed, e, i)`` — any worker can produce any shard without
  coordination, and checkpoint/resume needs only the step counter
  (``repro.ckpt`` stores it).
* **Shard-local generation**: each data-parallel rank generates only its
  slice, keyed by ``jax.random.fold_in(key, rank)``.
* **Zero I/O**: generation is jittable jnp; the host never feeds more
  than the PRNG key.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["TokenDataset", "make_lm_batches"]


@dataclasses.dataclass(frozen=True)
class TokenDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64      # Markov states (kept small: learnable fast)

    def _tables(self):
        key = jax.random.PRNGKey(self.seed)
        k1, k2 = jax.random.split(key)
        # Sparse-ish transition logits -> pronounced structure.
        trans = jax.random.gumbel(k1, (self.n_states, self.n_states)) * 2.0
        emit = jax.random.gumbel(k2, (self.n_states, self.vocab)) * 4.0
        return trans, emit

    @partial(jax.jit, static_argnums=0)
    def batch(self, step):
        """Batch for global step ``step``: dict(tokens, labels)."""
        trans, emit = self._tables()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)

        def sample_seq(k):
            ks, ke = jax.random.split(k)
            s0 = jax.random.randint(ks, (), 0, self.n_states)

            def body(s, kk):
                k_t, k_e = jax.random.split(kk)
                s_next = jax.random.categorical(k_t, trans[s])
                tok = jax.random.categorical(k_e, emit[s_next])
                return s_next, tok

            _, toks = jax.lax.scan(
                body, s0, jax.random.split(ke, self.seq_len + 1))
            return toks

        keys = jax.random.split(key, self.global_batch)
        toks = jax.vmap(sample_seq)(keys)
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}


def make_lm_batches(vocab: int, seq_len: int, global_batch: int,
                    seed: int = 0):
    """Iterator of batches; ``send``-free, restartable at any step."""
    ds = TokenDataset(vocab, seq_len, global_batch, seed)

    def at(step: int):
        return ds.batch(jnp.int32(step))

    return ds, at
