"""Streamed reconstruction: serve CT scans the way the LM engine serves
prompts (DESIGN.md §8)."""

from .engine import (ProjectionChunk, ReconstructionEngine,  # noqa: F401
                     ScanState)

__all__ = ["ProjectionChunk", "ReconstructionEngine", "ScanState"]
