"""Streamed reconstruction: serve CT scans the way the LM engine serves
prompts (DESIGN.md §8)."""

from .engine import ReconstructionEngine, ScanState  # noqa: F401

__all__ = ["ReconstructionEngine", "ScanState"]
