"""Streamed reconstruction engine: slot-based continuous batching for CT.

The paper's production setting is a C-arm that delivers projections *as a
stream* — end-to-end latency is set by how much of the filter and
back-projection work overlaps the acquisition, not by the back projection
alone (Treibig et al., arXiv:1104.5243).  This engine is the CT analogue
of :class:`repro.serving.engine.ServingEngine`:

* fixed ``n_slots`` concurrent reconstructions share one resident volume
  stack ``(n_slots, L, L, L)`` and one jitted fold step;
* an arriving chunk is FDK-filtered **on device the moment it arrives**,
  with Parker weights selected by its explicit *angle indices* (the
  ``filter_projections(..., angle_indices=...)`` contract — arrival order
  never has to match angle order);
* filtered projections accumulate in a per-scan staging buffer and are
  folded ``pbatch`` at a time through the batch-major loop nest
  (:func:`repro.core.backproject._backproject_batch_body`), so a chunk
  pays one volume pass, not one pass per projection (DESIGN.md §7/§8);
* every tick folds *all* ready slots in one vmapped+masked jitted call —
  B scans in flight cost one compiled step, mirroring the LM engine's
  ``_masked_decode_step`` slot discipline;
* finished scans retire, their slot is zeroed and immediately refilled
  from the admission queue (continuous batching).

Summation order within a volume follows arrival order, so a streamed
result matches the one-shot :func:`repro.core.backproject.reconstruct`
of the same projection set to fp32 rounding (~1e-5), not bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backproject import (GeomStatic, _backproject_batch_body,
                                    validate_strip_opts)
from repro.core.filtering import FilterPlan, apply_filter, make_filter_plan
from repro.core.geometry import Geometry

__all__ = ["ProjectionChunk", "ScanState", "ReconstructionEngine"]


@functools.partial(jax.jit,
                   static_argnames=("pad", "n_u", "n_proj", "scale"))
def _filter_chunk(projs, idx, cosw, hf, parker, pad, n_u, n_proj, scale):
    """On-device per-chunk FDK filter with angle-indexed Parker rows.

    Module-level jit: the compile cache is keyed on (chunk shape, plan
    statics), so every engine over the same geometry shares one trace
    per chunk size.
    """
    plan = FilterPlan(pad=pad, n_u=n_u, n_proj=n_proj, scale=scale,
                      hf=hf, cosw=cosw, parker=parker)
    pw = parker[idx] if parker is not None else None
    return apply_filter(projs, plan, pw)


@functools.partial(jax.jit, static_argnames=("gs", "plan"))
def _fold_slots(volumes, images, mats, mask, gs, plan):
    """One engine tick on device: fold a ``pbatch``-deep batch into every
    masked-in slot volume.

    ``volumes`` is ``(B, L, L, L)``, ``images`` ``(B, pbatch, n_v,
    n_u)``, ``mats`` ``(B, pbatch, 3, 4)``, ``mask`` ``(B,)`` bool;
    ``plan`` the resolved :class:`repro.dispatch.ExecutionPlan`.  The
    per-slot body is the batch-major volume pass of DESIGN.md §7 vmapped
    over slots; masked-out slots keep their volume bit-identical (their
    staged images are zero anyway, but the merge makes the guarantee
    unconditional — same idiom as the LM engine's masked decode step).
    """

    def one(vol, imgs, ms):
        return _backproject_batch_body(vol, imgs, ms, gs, plan,
                                       jnp.int32(0))

    new = jax.vmap(one)(volumes, images, mats)
    return jnp.where(mask[:, None, None, None], new, volumes)


@dataclasses.dataclass(frozen=True)
class ProjectionChunk:
    """One typed arrival payload: ``k`` raw projections with their
    matrices and global angle indices.

    The one submit currency shared by :meth:`ReconstructionEngine.submit`
    and the front door (:class:`repro.serving.ct_frontdoor.CTFrontDoor`).
    ``projections`` is ``(k, n_v, n_u)`` (or a single ``(n_v, n_u)``
    image), ``matrices`` ``(k, 3, 4)`` (or one ``(3, 4)``), and
    ``angle_indices`` the ``k`` *global* angle indices (or a scalar) —
    raw line integrals, filtered by the consumer on arrival.
    """

    projections: object
    matrices: object
    angle_indices: object

    @property
    def n(self) -> int:
        """Number of projections carried."""
        shape = np.shape(self.projections)
        return 1 if len(shape) == 2 else int(shape[0])

    def arrays(self):
        """Normalise to ``(k, n_v, n_u) f32, (k, 3, 4) f64, (k,) i32``."""
        projs = jnp.asarray(self.projections, jnp.float32)
        if projs.ndim == 2:
            projs = projs[None]
        mats = np.asarray(self.matrices, np.float64).reshape(-1, 3, 4)
        idx = np.atleast_1d(np.asarray(self.angle_indices, np.int32))
        return projs, mats, idx


# The deprecated positional ``submit(sid, projection, matrix, angle_index)``
# form warns exactly once per process — every further call is silent, so a
# chunk-per-chunk streaming loop does not drown the log.
_POSITIONAL_SUBMIT_WARNED = False


@dataclasses.dataclass
class ScanState:
    """One reconstruction in flight (the CT analogue of ``Request``)."""

    sid: int
    n_proj: int                       # projections this scan will deliver
    received: int = 0
    folded: int = 0
    # Staged (filtered image, matrix) pairs awaiting a volume pass.
    pending: list = dataclasses.field(default_factory=list)
    volume: jnp.ndarray | None = None  # set at retirement
    done: bool = False

    @property
    def complete(self) -> bool:
        """All projections submitted (folds may still be outstanding)."""
        return self.received >= self.n_proj


class ReconstructionEngine:
    """Accept projection chunks in arrival order; serve volumes.

    ``submit(sid, projection, matrix, angle_index)`` takes one ``(n_v,
    n_u)`` projection (scalar ``angle_index``) or a ``(k, n_v, n_u)``
    chunk (``angle_index`` array of k global angle indices) — raw line
    integrals, filtered here on arrival.  ``strategy="auto"`` resolves
    through the process dispatcher exactly like ``reconstruct`` —
    including in-situ first-call selection (the timing problem is
    synthesized from the geometry, so resolution happens here at
    construction, before any projection arrives); when the resolved
    plan's tuned Pallas batch kernel beat the jnp nest
    (``plan.use_pallas``), the fold step runs that kernel per ready
    slot instead of the vmapped jnp body.  Strip windows are validated
    against the host planner per submitted chunk (memoised), so an
    undersized window raises instead of dropping taps.
    """

    def __init__(self, geom: Geometry, *, n_slots: int = 4,
                 strategy: str = "strip2", pbatch: int | None = None,
                 short_scan: bool | None = None, validate: bool = True,
                 auto_step: bool = True, plan=None, **opts):
        self.geom = geom
        self.gs = GeomStatic.of(geom)
        if plan is None:
            from repro.dispatch import get_dispatcher

            plan = get_dispatcher().resolve(geom, strategy, opts,
                                            pbatch=pbatch)
        # ``self.plan`` is the *filter* plan (pre-dates the dispatcher);
        # the execution plan lives under ``exec_plan``.
        self.exec_plan = plan
        self.strategy = plan.strategy
        self.opts = plan.jnp_opts()
        # Tuned kernel fold: only taken when the measured evidence says
        # the Pallas batch kernel beat the jnp nest for this key.
        self._pallas_kwargs = (plan.pallas_opts() if plan.use_pallas
                               else None)
        if pbatch is not None:
            eff = int(pbatch)
        elif self._pallas_kwargs is not None:
            # The kernel decision was timed at its own batch depth.
            eff = int(self._pallas_kwargs.get("pbatch", plan.pbatch))
        else:
            eff = plan.pbatch
        self.pbatch = max(1, eff)
        if self._pallas_kwargs is not None:
            self._pallas_kwargs["pbatch"] = self.pbatch
        self.validate = validate
        self.auto_step = auto_step
        self.n_slots = int(n_slots)
        self.plan = make_filter_plan(geom, short_scan)
        self._volumes = jnp.zeros((self.n_slots,) + (geom.L,) * 3,
                                  jnp.float32)
        self._zero_image = jnp.zeros((geom.n_v, geom.n_u), jnp.float32)
        self.slot_scan: list[int | None] = [None] * self.n_slots
        self.scans: dict[int, ScanState] = {}
        self.queue: list[int] = []
        self.slot_history: list[tuple[int, int]] = []  # (slot, sid)
        self.stats = {"folds": 0, "fold_ticks": 0, "retired": 0,
                      "pallas_folds": 0, "aborted": 0}
        self._next_sid = 0

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def begin_scan(self, n_proj: int | None = None) -> int:
        """Register a new reconstruction; returns its scan id.

        The scan occupies a volume slot immediately when one is free,
        else it queues (its chunks are still filtered and staged on
        arrival) until a running scan retires — continuous batching.

        ``n_proj=None`` means a full scan (``geom.n_proj``).  An explicit
        non-positive count is a caller bug and raises — a truthiness
        check here once turned ``n_proj=0`` into a silent full scan.
        """
        if n_proj is not None and int(n_proj) <= 0:
            raise ValueError(
                f"begin_scan: n_proj must be positive, got {n_proj!r} "
                f"(pass None for a full scan)")
        sid = self._next_sid
        self._next_sid += 1
        self.scans[sid] = ScanState(
            sid=sid,
            n_proj=int(n_proj) if n_proj is not None else self.geom.n_proj)
        self.queue.append(sid)
        self._admit()
        return sid

    def _free_slots(self):
        return [i for i, s in enumerate(self.slot_scan) if s is None]

    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            sid = self.queue.pop(0)
            self.slot_scan[slot] = sid
            self.slot_history.append((slot, sid))

    # ------------------------------------------------------------------
    # Arrival path
    # ------------------------------------------------------------------
    def submit(self, sid: int, chunk, matrix=None, angle_index=None):
        """Stage one :class:`ProjectionChunk` of scan ``sid``.

        Filters on device now — with the Parker rows of the *submitted
        angle indices* — and stages the result for the next fold tick.
        Arrival order is free: chunks may be shuffled, interleaved
        across scans, and split arbitrarily.

        The blessed form is ``submit(sid, ProjectionChunk(...))``.  The
        pre-facade positional form ``submit(sid, projection, matrix,
        angle_index)`` still works as a thin shim but emits one
        ``DeprecationWarning`` per process.
        """
        global _POSITIONAL_SUBMIT_WARNED
        if not isinstance(chunk, ProjectionChunk):
            if matrix is None or angle_index is None:
                raise TypeError(
                    "submit takes a ProjectionChunk (or the deprecated "
                    "positional (projection, matrix, angle_index) triple)")
            if not _POSITIONAL_SUBMIT_WARNED:
                _POSITIONAL_SUBMIT_WARNED = True
                warnings.warn(
                    "submit(sid, projection, matrix, angle_index) is "
                    "deprecated; pass submit(sid, ProjectionChunk("
                    "projection, matrix, angle_index))",
                    DeprecationWarning, stacklevel=2)
            chunk = ProjectionChunk(chunk, matrix, angle_index)
        elif matrix is not None or angle_index is not None:
            raise TypeError(
                "submit(sid, ProjectionChunk) takes no separate matrix/"
                "angle_index arguments")
        scan = self.scans[sid]
        if scan.done:
            raise ValueError(f"scan {sid} already finished")
        projs, mats, idx = chunk.arrays()
        k = projs.shape[0]
        if mats.shape[0] != k or idx.shape != (k,):
            raise ValueError(
                f"chunk of {k} projection(s) needs {k} matrices and {k} "
                f"angle indices; got {mats.shape[0]} and {idx.shape}")
        if idx.min() < 0 or idx.max() >= self.geom.n_proj:
            raise ValueError(
                f"angle indices must lie in [0, {self.geom.n_proj})")
        if scan.received + k > scan.n_proj:
            raise ValueError(
                f"scan {sid} declared {scan.n_proj} projections; "
                f"{scan.received + k} submitted")
        if self.validate and self._pallas_kwargs is None:
            # The kernel fold path validates its own tile config at fold
            # time (pallas_backproject_batch(validate=...)).
            validate_strip_opts(self.geom, mats, self.strategy, self.opts)
        filt = _filter_chunk(
            projs, jnp.asarray(idx), self.plan.cosw, self.plan.hf,
            self.plan.parker, pad=self.plan.pad, n_u=self.plan.n_u,
            n_proj=self.plan.n_proj, scale=self.plan.scale)
        mats32 = np.asarray(mats, np.float32)
        for i in range(k):
            scan.pending.append((filt[i], mats32[i]))
        scan.received += k
        if self.auto_step:
            self.step()

    # ------------------------------------------------------------------
    # Fold path
    # ------------------------------------------------------------------
    def _take_batch(self, scan: ScanState):
        """Up to ``pbatch`` staged projections, zero-padded to depth.

        Padding images are zero (their contribution is exactly 0.0) and
        padding matrices repeat a real, validated matrix so the strip
        planner's coverage guarantee extends to the pad rows.
        """
        take = scan.pending[:self.pbatch]
        del scan.pending[:self.pbatch]
        imgs = [img for img, _ in take]
        mats = [m for _, m in take]
        while len(imgs) < self.pbatch:
            imgs.append(self._zero_image)
            mats.append(mats[0])
        return jnp.stack(imgs), np.stack(mats), len(take)

    def step(self) -> bool:
        """One engine tick: fold every ready slot, retire finished scans.

        A slot is *ready* when it holds a full ``pbatch`` of staged
        projections, or its scan is complete (the sub-``pbatch``
        remainder folds zero-padded — same compiled step, DESIGN.md §8).
        All ready slots fold in one vmapped jitted call.  Returns True
        when any fold or retirement happened.
        """
        self._admit()
        ready = []
        for slot, sid in enumerate(self.slot_scan):
            if sid is None:
                continue
            scan = self.scans[sid]
            if len(scan.pending) >= self.pbatch \
                    or (scan.complete and scan.pending):
                ready.append((slot, scan))
        progressed = False
        if ready and self._pallas_kwargs is not None:
            # Tuned kernel fold: the Pallas batch winner, one launch per
            # ready slot (zero-padded staging contributes exactly 0, so
            # the static batch shape is shared with the jnp path).
            from repro.kernels.backproject_ops import \
                pallas_backproject_batch

            for slot, scan in ready:
                imgs, ms, n = self._take_batch(scan)
                vol = pallas_backproject_batch(
                    self._volumes[slot], imgs, ms, self.geom,
                    validate=self.validate, **self._pallas_kwargs)
                self._volumes = self._volumes.at[slot].set(vol)
                scan.folded += n
                self.stats["folds"] += n
                self.stats["pallas_folds"] += n
            self.stats["fold_ticks"] += 1
            progressed = True
        elif ready:
            images = [self._zero_image[None].repeat(self.pbatch, axis=0)
                      ] * self.n_slots
            mats = [np.broadcast_to(np.eye(3, 4, dtype=np.float32),
                                    (self.pbatch, 3, 4))] * self.n_slots
            mask = np.zeros((self.n_slots,), bool)
            for slot, scan in ready:
                imgs, ms, n = self._take_batch(scan)
                images[slot] = imgs
                mats[slot] = ms
                mask[slot] = True
                scan.folded += n
                self.stats["folds"] += n
            self._volumes = _fold_slots(
                self._volumes, jnp.stack(images),
                jnp.asarray(np.stack(mats)), jnp.asarray(mask), self.gs,
                self.exec_plan)
            self.stats["fold_ticks"] += 1
            progressed = True
        progressed |= self._retire()
        return progressed

    def _retire(self) -> bool:
        any_retired = False
        for slot, sid in enumerate(self.slot_scan):
            if sid is None:
                continue
            scan = self.scans[sid]
            if scan.complete and not scan.pending:
                scan.volume = self._volumes[slot]
                scan.done = True
                self._volumes = self._volumes.at[slot].set(0.0)
                self.slot_scan[slot] = None
                self.stats["retired"] += 1
                any_retired = True
                del self.slot_history[:-4096]   # bound a long-lived server
        if any_retired:
            self._admit()
        return any_retired

    # ------------------------------------------------------------------
    # Results
    # ------------------------------------------------------------------
    def drain(self, max_ticks: int = 100_000) -> int:
        """Fold until no slot can make progress; returns ticks run.

        Scans that have not submitted all their projections keep their
        sub-``pbatch`` staging buffers — drain never forces a partial
        scan to a (wrong) early result.
        """
        ticks = 0
        while ticks < max_ticks and self.step():
            ticks += 1
        return ticks

    def result(self, sid: int, pop: bool = False) -> jnp.ndarray:
        """The finished ``(L, L, L)`` volume of scan ``sid``.

        ``pop=True`` releases the scan's state after fetching — a
        long-running server must do one of ``pop``/:meth:`release` per
        scan, or retired volumes (``L³·4`` bytes each) accumulate in
        ``self.scans`` forever.
        """
        scan = self.scans[sid]
        if not scan.done:
            raise ValueError(
                f"scan {sid} not finished: {scan.received}/{scan.n_proj} "
                f"submitted, {len(scan.pending)} staged"
                + ("" if scan.complete else " (more submissions expected)"))
        vol = scan.volume
        if pop:
            self.release(sid)
        return vol

    def release(self, sid: int) -> None:
        """Drop a *finished* scan's state (and its retained volume)."""
        scan = self.scans.get(sid)
        if scan is None:
            return
        if not scan.done:
            raise ValueError(f"scan {sid} still active; cannot release")
        del self.scans[sid]

    def abort_scan(self, sid: int) -> None:
        """Drop scan ``sid`` mid-flight (the front door's cancel path).

        Staged projections are discarded, the scan's slot (if it holds
        one) is retired and zeroed, and the freed slot refills from the
        admission queue immediately.  The next occupant starts from the
        same all-zero volume a fresh slot gets, so abort-then-reuse is
        bit-clean.  Unknown (or already-released) sids raise; aborting a
        *finished* scan just drops its retained volume.
        """
        scan = self.scans.pop(sid, None)
        if scan is None:
            raise ValueError(f"abort_scan: unknown scan {sid}")
        if sid in self.queue:
            self.queue.remove(sid)
        for slot, owner in enumerate(self.slot_scan):
            if owner == sid:
                self._volumes = self._volumes.at[slot].set(0.0)
                self.slot_scan[slot] = None
        scan.pending.clear()
        scan.done = True
        self.stats["aborted"] += 1
        self._admit()

    @property
    def active(self) -> int:
        """Scans currently holding slots or queued."""
        return sum(s is not None for s in self.slot_scan) + len(self.queue)

    @property
    def free_slots(self) -> int:
        """Slots an admission would get *right now* (empty slots not
        already claimed by the engine's own FIFO queue) — the capacity
        signal the front door's policies schedule against."""
        empty = sum(s is None for s in self.slot_scan)
        return max(0, empty - len(self.queue))
