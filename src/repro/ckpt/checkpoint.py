"""Sharded, atomic, async checkpointing (no orbax offline — built here).

Format: one directory per step::

    <dir>/step_000123/
        manifest.json       # pytree structure, shapes, dtypes, step
        arr_00000.npy ...   # one .npy per leaf (host-gathered shard-0 view)

Properties the fault-tolerance story needs:

* **Atomicity**: writes go to ``step_X.tmp/`` and are ``rename``d into
  place — a preempted writer never leaves a half-checkpoint that restore
  could pick up (rename is atomic on POSIX).
* **Async**: ``CheckpointManager.save_async`` snapshots to host memory
  synchronously (cheap) and writes on a daemon thread, overlapping the
  next training steps — the classic hide-the-checkpoint-latency trick.
* **Elastic resume**: arrays are saved *unsharded* (host-gathered) and
  restored with ``jax.device_put(. , sharding)`` against whatever mesh
  the restart runs on — a 256-chip checkpoint restores onto 512 chips or
  onto 1 CPU device (tested in ``tests/test_ckpt.py``).
* **Retention**: ``keep`` newest checkpoints are retained, older ones
  garbage-collected after a successful save.

At true 1000-node scale you would write per-shard files from each host
(same manifest layout, ``arr_XXXXX.shard_YYY.npy``); the single-writer
path here is what the single-process dry-run environment can exercise.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]

_MANIFEST = "manifest.json"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:09d}")


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3):
    """Atomic synchronous save of ``tree`` at ``step``."""
    leaves, treedef = jax.tree.flatten(tree)
    final = _step_dir(directory, step)
    tmp = final + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "treedef": str(treedef), "n_leaves":
                len(leaves), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        # Store raw bytes: np.save cannot represent extension dtypes
        # (bfloat16, int4, ...) — the manifest carries dtype + shape.
        np.save(os.path.join(tmp, f"arr_{i:05d}.npy"),
                arr.reshape(-1).view(np.uint8))
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.exists(os.path.join(directory, name,
                                                _MANIFEST)):
            out.append(int(name.removeprefix("step_")))
    return sorted(out)


def load_checkpoint(directory: str, tree_like, *, step: int | None = None,
                    shardings=None):
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional pytree (same structure) of ``Sharding``s —
    this is the elastic-resume path: leaves are placed directly onto the
    current mesh regardless of the mesh that saved them.
    Returns ``(tree, step)``.
    """
    steps = all_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    d = _step_dir(directory, step)
    leaves_like, treedef = jax.tree.flatten(tree_like)
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(leaves_like)} — structure changed?")
    shard_leaves = (treedef.flatten_up_to(shardings) if shardings
                    is not None else [None] * len(leaves_like))
    out = []
    for i, (like, sh) in enumerate(zip(leaves_like, shard_leaves)):
        meta = manifest["leaves"][i]
        raw = np.load(os.path.join(d, f"arr_{i:05d}.npy"))
        arr = raw.view(np.dtype(meta["dtype"])).reshape(meta["shape"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out), step


class CheckpointManager:
    """Async checkpointing with bounded in-flight writes."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save_async(self, step: int, tree):
        """Snapshot to host now; write on a daemon thread."""
        self.wait()                     # at most one write in flight
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                keep=self.keep)
            except Exception as e:      # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest_step(self) -> int | None:
        steps = all_steps(self.directory)
        return steps[-1] if steps else None

    def restore(self, tree_like, shardings=None):
        return load_checkpoint(self.directory, tree_like,
                               shardings=shardings)
