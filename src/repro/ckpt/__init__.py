"""Checkpointing: atomic sharded save/restore, async writer, elastic resume."""

from .checkpoint import (  # noqa: F401
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)
