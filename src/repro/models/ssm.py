"""SSM blocks: Mamba (S6) and xLSTM (mLSTM / sLSTM).

These are the *streaming* architectures of the zoo — the Part-1-like
workloads of the paper's taxonomy (pure elementwise/matmul dataflow, no
scattered access), which is why ``long_500k`` runs only for them: decode
carries O(1) recurrent state instead of a KV cache.

Implementation notes
--------------------
* **Mamba** follows the S6 recurrence ``h_t = exp(dt*A) h_{t-1} + dt*B x``
  with input-dependent (selective) ``B, C, dt``.  Training/prefill uses a
  chunked scan: ``lax.scan`` over sequence chunks with an associative scan
  inside each chunk — peak activation memory is ``O(B * chunk * d_inner *
  d_state)`` per device instead of ``O(B * S * ...)``, the same memory
  shape the official CUDA kernel achieves by fusion (hardware adaptation
  note in DESIGN.md: the TPU-native form is scan-blocking, not a fused
  SRAM kernel).
* **mLSTM** uses the chunkwise-parallel form: within a chunk the matrix
  memory is applied as decayed attention; across chunks a recurrent
  ``(hd x hd)`` state ``C`` and normaliser ``n`` are carried with
  max-stabilised exponential gates (arXiv:2405.04517, eqs. 19-27).
* **sLSTM** is inherently sequential (scalar memory mixing across the
  head dim); it scans one step per token.  Cheap per step; xlstm-125m
  places it on every second block.

``d_inner`` is ``tp``-sharded; all recurrences are batch-parallel.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_constraint

from .layers import Param, dense, init_dense

__all__ = [
    "init_mamba", "mamba_forward", "mamba_step", "init_mamba_cache",
    "init_mlstm", "mlstm_forward", "mlstm_step", "init_mlstm_cache",
    "init_slstm", "slstm_forward", "slstm_step", "init_slstm_cache",
]


# ======================================================================
# Mamba (S6)
# ======================================================================

def init_mamba(p: Param, cfg):
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.d_state
    init_dense(p, "in_proj", d, 2 * di, ("fsdp", "tp"))
    p.add("conv_w", (cfg.d_conv, di), (None, "tp"),
          scale=1.0 / math.sqrt(cfg.d_conv))
    p.add("conv_b", (di,), ("tp",), init="zeros")
    init_dense(p, "x_proj", di, 2 * ds + 1, ("tp", None))
    p.add("dt_bias", (di,), ("tp",), init="zeros")
    p.add("A_log", (di, ds), ("tp", None), init="ones")
    p.add("D", (di,), ("tp",), init="ones")
    init_dense(p, "out_proj", di, d, ("tp", "fsdp"))


def _mamba_conv(x, w, b, carry=None):
    """Depthwise causal conv along seq.  ``x``: (B, S, di)."""
    K = w.shape[0]
    if carry is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = carry
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_carry = xp[:, -(K - 1):] if K > 1 else pad
    return out + b, new_carry


def _ssm_scan_chunk(dA, dBx, h0):
    """Associative scan of ``h_t = dA_t * h_{t-1} + dBx_t`` over a chunk.

    ``dA``, ``dBx``: (B, C, di, ds); ``h0``: (B, di, ds).
    Returns (states (B, C, di, ds), h_last).
    """
    def combine(a, b):
        return (a[0] * b[0], b[0] * a[1] + b[1])

    A, Bx = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    states = A * h0[:, None] + Bx
    return states, states[:, -1]


def mamba_forward(params, cfg, x, *, chunk: int = 256,
                  dtype=jnp.bfloat16, return_state: bool = False):
    """Full-sequence selective SSM.  ``x``: (B, S, d) -> (B, S, d).

    ``return_state=True`` additionally returns the decode cache after the
    last token (prefill path).  ``chunk`` trades inter-chunk carry I/O
    against in-chunk associative-scan memory; 256 measured best on the
    jamba train cell (64/128/256 -> memory term 75.8/56.7/47.8 s,
    EXPERIMENTS.md §Perf).
    """
    B, S, _ = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    xz = dense(params, "in_proj", x, dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = shard_constraint(xi, ("batch", None, "tp"))
    xi, conv_tail = _mamba_conv(xi, params["conv_w"].astype(dtype),
                                params["conv_b"].astype(dtype))
    xi = jax.nn.silu(xi)

    bcd = dense(params, "x_proj", xi, dtype).astype(jnp.float32)
    Bm, Cm, dt = (bcd[..., :ds], bcd[..., ds:2 * ds], bcd[..., -1:])
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))      # (di, ds)
    xf = xi.astype(jnp.float32)

    if S % chunk:
        chunk = S                                          # smoke tests
    n_chunks = S // chunk

    def seq_chunks(a):
        return a.reshape(B, n_chunks, chunk, *a.shape[2:]).swapaxes(0, 1)

    def step(h, blk):
        xb, Bb, Cb, dtb = blk        # (B,C,di), (B,C,ds), (B,C,ds), (B,C,di)
        dA = jnp.exp(dtb[..., None] * A)                   # (B,C,di,ds)
        dBx = (dtb * xb)[..., None] * Bb[:, :, None, :]
        states, h_last = _ssm_scan_chunk(dA, dBx, h)
        y = jnp.einsum("bcds,bcs->bcd", states, Cb)
        return h_last, y

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, (seq_chunks(xf), seq_chunks(Bm),
                                         seq_chunks(Cm), seq_chunks(dt)))
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    y = y + xf * params["D"].astype(jnp.float32)
    y = (y.astype(dtype)) * jax.nn.silu(z)
    out = dense(params, "out_proj", y, dtype)
    if return_state:
        return out, {"conv": conv_tail.astype(jnp.float32), "h": h_last}
    return out


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    }


def mamba_step(params, cfg, x, cache, *, dtype=jnp.bfloat16):
    """Single-token recurrent step.  ``x``: (B, 1, d)."""
    di, ds = cfg.d_inner, cfg.d_state
    xz = dense(params, "in_proj", x, dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_carry = _mamba_conv(xi, params["conv_w"].astype(dtype),
                                 params["conv_b"].astype(dtype),
                                 carry=cache["conv"].astype(dtype))
    xi = jax.nn.silu(xi)
    bcd = dense(params, "x_proj", xi, dtype).astype(jnp.float32)
    Bm, Cm, dt = bcd[..., :ds], bcd[..., ds:2 * ds], bcd[..., -1:]
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xf = xi.astype(jnp.float32)[:, 0]                       # (B, di)
    dA = jnp.exp(dt[:, 0, :, None] * A)                     # (B?, di, ds)
    h = cache["h"] * dA + (dt[:, 0] * xf)[..., None] * Bm[:, 0, None, :]
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0])[:, None]
    y = y + xf[:, None] * params["D"].astype(jnp.float32)
    y = y.astype(dtype) * jax.nn.silu(z)
    out = dense(params, "out_proj", y, dtype)
    return out, {"conv": conv_carry.astype(cache["conv"].dtype), "h": h}


# ======================================================================
# mLSTM (matrix LSTM, chunkwise-parallel)
# ======================================================================

def init_mlstm(p: Param, cfg):
    d, di = cfg.d_model, cfg.d_inner
    init_dense(p, "qkv", d, 3 * di, ("fsdp", "tp"))
    init_dense(p, "gates", d, 2 * cfg.n_heads, ("fsdp", "tp"))
    init_dense(p, "up", d, di, ("fsdp", "tp"))
    init_dense(p, "out_proj", di, d, ("tp", "fsdp"))


def _mlstm_heads(cfg, t):
    B, S, di = t.shape
    H = cfg.n_heads
    return t.reshape(B, S, H, di // H)


def mlstm_forward(params, cfg, x, *, chunk: int = 128,
                  dtype=jnp.bfloat16, return_state: bool = False):
    """Chunkwise-parallel mLSTM.  ``x``: (B, S, d) -> (B, S, d)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    di = cfg.d_inner
    hd = di // H
    qkv = dense(params, "qkv", x, dtype)
    q, k, v = (_mlstm_heads(cfg, t) for t in jnp.split(qkv, 3, axis=-1))
    gates = dense(params, "gates", x, dtype).astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                  # (B, S, H)
    logf = -jax.nn.softplus(-fg)                           # log sigmoid

    if S % chunk:
        chunk = S
    n = S // chunk

    def to_chunks(t):
        return t.reshape(B, n, chunk, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = (to_chunks(t.astype(jnp.float32)) for t in (q, k, v))
    ic, fc = to_chunks(ig), to_chunks(logf)
    scale = 1.0 / math.sqrt(hd)

    def step(carry, blk):
        C, nvec, m = carry               # (B,H,hd,hd), (B,H,hd), (B,H)
        qb, kb, vb, ib, fb = blk
        csum = jnp.cumsum(fb, axis=1)                      # (B, C, H)
        total = csum[:, -1]
        # Stabiliser: since log-sigmoid forget gates are <= 0, every
        # exponent below (intra dmat, inter decay, state update) is
        # bounded by max(m, max_k ig_k) — one chunk-level stabiliser
        # suffices (xLSTM eq. 19-27 adapted to chunkwise form).
        m_new = jnp.maximum(m, jnp.max(ib, axis=1))
        # Intra-chunk decayed attention.
        dmat = (csum[:, :, None] - csum[:, None, :]
                + ib[:, None, :])                           # (B,Cq,Ck,H)
        qi = jnp.arange(chunk)
        causal = qi[:, None] >= qi[None, :]
        dmat = jnp.where(causal[None, :, :, None],
                         dmat - m_new[:, None, None, :], -jnp.inf)
        att = jnp.einsum("bqhd,bkhd->bqkh", qb, kb) * scale
        w = att * jnp.exp(dmat)
        intra = jnp.einsum("bqkh,bkhd->bqhd", w, vb)
        # Inter-chunk: apply carried state with decay to each position.
        dec = jnp.exp(csum + m[:, None] - m_new[:, None])   # (B,C,H)
        inter = jnp.einsum("bqhd,bhde->bqhe", qb * dec[..., None], C) \
            * scale
        norm = jnp.einsum("bqkh->bqh", w) \
            + jnp.einsum("bqhd,bhd->bqh", qb * dec[..., None], nvec) \
            * scale
        y = (intra + inter) / jnp.maximum(
            jnp.abs(norm)[..., None], jnp.exp(-m_new)[:, None, ..., None])
        # State update for the next chunk: position k decays by the
        # remaining chunk gates, exponent ig_k + (total - csum_k) - m_new.
        kdec = jnp.exp(ib + total[:, None] - csum - m_new[:, None])
        C_new = C * jnp.exp(total + m - m_new)[..., None, None] \
            + jnp.einsum("bkhd,bkhe->bhde", kb * kdec[..., None], vb)
        n_new = nvec * jnp.exp(total + m - m_new)[..., None] \
            + jnp.einsum("bkhd->bhd", kb * kdec[..., None])
        return (C_new, n_new, m_new), y

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    (Cf, nf, mf), ys = jax.lax.scan(step, (C0, n0, m0),
                                    (qc, kc, vc, ic, fc))
    y = ys.swapaxes(0, 1).reshape(B, S, di).astype(dtype)
    y = y * jax.nn.silu(dense(params, "up", x, dtype))
    out = dense(params, "out_proj", y, dtype)
    if return_state:
        return out, {"C": Cf, "n": nf, "m": mf}
    return out


def init_mlstm_cache(cfg, batch: int, dtype=jnp.float32):
    H = cfg.n_heads
    hd = cfg.d_inner // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
    }


def mlstm_step(params, cfg, x, cache, *, dtype=jnp.bfloat16):
    """O(1)-state decode step (the reason xlstm runs ``long_500k``)."""
    B = x.shape[0]
    H = cfg.n_heads
    di = cfg.d_inner
    hd = di // H
    qkv = dense(params, "qkv", x, dtype)
    q, k, v = (_mlstm_heads(cfg, t)[:, 0].astype(jnp.float32)
               for t in jnp.split(qkv, 3, axis=-1))        # (B, H, hd)
    gates = dense(params, "gates", x, dtype).astype(jnp.float32)[:, 0]
    ig, fg = jnp.split(gates, 2, axis=-1)                  # (B, H)
    logf = -jax.nn.softplus(-fg)
    C, nvec, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(logf + m, ig)
    fdec = jnp.exp(logf + m - m_new)
    idec = jnp.exp(ig - m_new)
    C_new = C * fdec[..., None, None] \
        + idec[..., None, None] * k[..., :, None] * v[..., None, :]
    n_new = nvec * fdec[..., None] + idec[..., None] * k
    scale = 1.0 / math.sqrt(hd)
    num = jnp.einsum("bhd,bhde->bhe", q, C_new) * scale
    den = jnp.einsum("bhd,bhd->bh", q, n_new) * scale
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    y = y.reshape(B, 1, di).astype(dtype)
    y = y * jax.nn.silu(dense(params, "up", x, dtype))
    out = dense(params, "out_proj", y, dtype)
    return out, {"C": C_new, "n": n_new, "m": m_new}


# ======================================================================
# sLSTM (scalar memory, sequential)
# ======================================================================

def init_slstm(p: Param, cfg):
    d, di = cfg.d_model, cfg.d_inner
    init_dense(p, "zifo", d, 4 * di, ("fsdp", "tp"))
    p.add("r_zifo", (4, di), (None, "tp"),
          scale=1.0 / math.sqrt(di))                       # diag recurrence
    init_dense(p, "out_proj", di, d, ("tp", "fsdp"))


def _slstm_cell(zifo, r, state):
    """One sLSTM step with exponential gating (per-feature recurrence)."""
    c, nvec, h, m = state
    z_in, i_in, f_in, o_in = jnp.split(zifo, 4, axis=-1)
    z = jnp.tanh(z_in + r[0] * h)
    ig = i_in + r[1] * h
    fg = f_in + r[2] * h
    o = jax.nn.sigmoid(o_in + r[3] * h)
    logf = -jax.nn.softplus(-fg)
    m_new = jnp.maximum(logf + m, ig)
    c_new = c * jnp.exp(logf + m - m_new) + jnp.exp(ig - m_new) * z
    n_new = nvec * jnp.exp(logf + m - m_new) + jnp.exp(ig - m_new)
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(params, cfg, x, *, dtype=jnp.bfloat16,
                  return_state: bool = False, unroll: int = 1):
    """Sequential scan over tokens.  ``x``: (B, S, d).

    ``unroll`` was hillclimb LM-1 iteration 1 (amortise carry traffic by
    unrolling the recurrence): REFUTED — XLA does not fuse across the
    sequential dependency chain, measured bytes dropped only 2% while
    compile time grew 27x, so the default stays 1 (EXPERIMENTS.md §Perf).
    The real fix is the fused VMEM-resident kernel in
    ``repro.kernels.slstm``.
    """
    B, S, _ = x.shape
    di = cfg.d_inner
    zifo = dense(params, "zifo", x, dtype).astype(jnp.float32)
    r = params["r_zifo"].astype(jnp.float32)

    def step(state, zt):
        new = _slstm_cell(zt, r, state)
        return new, new[2]

    init = tuple(jnp.zeros((B, di), jnp.float32) for _ in range(3)) \
        + (jnp.full((B, di), -jnp.inf, jnp.float32),)
    (c, nv, h, m), hs = jax.lax.scan(step, init, zifo.swapaxes(0, 1),
                                     unroll=min(unroll, S))
    y = hs.swapaxes(0, 1).astype(dtype)
    out = dense(params, "out_proj", y, dtype)
    if return_state:
        return out, {"c": c, "n": nv, "h": h, "m": m}
    return out


def init_slstm_cache(cfg, batch: int, dtype=jnp.float32):
    di = cfg.d_inner
    return {
        "c": jnp.zeros((batch, di), jnp.float32),
        "n": jnp.zeros((batch, di), jnp.float32),
        "h": jnp.zeros((batch, di), jnp.float32),
        "m": jnp.full((batch, di), -jnp.inf, jnp.float32),
    }


def slstm_step(params, cfg, x, cache, *, dtype=jnp.bfloat16):
    zifo = dense(params, "zifo", x, dtype).astype(jnp.float32)[:, 0]
    r = params["r_zifo"].astype(jnp.float32)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, nv, h, m = _slstm_cell(zifo, r, state)
    y = h[:, None].astype(dtype)
    out = dense(params, "out_proj", y, dtype)
    return out, {"c": c, "n": nv, "h": h, "m": m}
