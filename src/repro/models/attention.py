"""GQA attention: chunked-softmax training/prefill + KV-cache decode.

Three entry points sharing one parameter set:

* :func:`attention_train` — full-sequence causal attention.  Above
  ``cfg.attn_chunk`` keys the score matrix is never materialised: an
  online-softmax ``lax.scan`` over KV blocks keeps activation memory
  ``O(S * chunk)`` (flash-attention recurrence, which is what lets the
  ``prefill_32k`` cells fit — see EXPERIMENTS.md §Dry-run).
* :func:`attention_decode` — one new token against a ``(B, T, KV, hd)``
  cache; pure streaming (the KV read is the *structured* access pattern
  the paper contrasts with true scattered gathers).
* cross-attention (Whisper) reuses ``attention_train`` without the causal
  mask.

Sharding: heads are ``tp``, batch is ``batch``; KV heads replicate within
a TP group when ``n_kv_heads < tp`` (GQA kv=2/4/8 cells).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_constraint

from .layers import Param, apply_rope, dense, init_dense

__all__ = ["init_attention", "attention_train", "attention_decode",
           "init_kv_cache"]

_NEG = -1e30


def init_attention(p: Param, cfg, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    init_dense(p, "wq", d, cfg.n_heads * hd, ("fsdp", "tp"),
               bias=cfg.qkv_bias)
    init_dense(p, "wk", d, cfg.n_kv_heads * hd, ("fsdp", "tp"),
               bias=cfg.qkv_bias)
    init_dense(p, "wv", d, cfg.n_kv_heads * hd, ("fsdp", "tp"),
               bias=cfg.qkv_bias)
    init_dense(p, "wo", cfg.n_heads * hd, d, ("tp", "fsdp"))


def _rope_one(t, positions, cfg):
    """Apply the configured RoPE variant to one (B, S, H, hd) tensor."""
    if positions is None or cfg.rope == "none":
        return t
    return apply_rope(t, t, positions, cfg.hd, cfg.rope_theta, cfg.rope)[0]


def _qkv(params, cfg, xq, xkv, positions, kv_positions, dtype):
    B, S = xq.shape[:2]
    T = xkv.shape[1]
    hd = cfg.hd
    q = dense(params, "wq", xq, dtype).reshape(B, S, cfg.n_heads, hd)
    k = dense(params, "wk", xkv, dtype).reshape(B, T, cfg.n_kv_heads, hd)
    v = dense(params, "wv", xkv, dtype).reshape(B, T, cfg.n_kv_heads, hd)
    q = _rope_one(q, positions, cfg)
    k = _rope_one(k, kv_positions, cfg)
    q = shard_constraint(q, ("batch", None, "tp", None))
    k = shard_constraint(k, ("batch", None, "tp", None))
    v = shard_constraint(v, ("batch", None, "tp", None))
    return q, k, v


def _group(q, n_kv):
    """(B, S, H, hd) -> (B, S, KV, G, hd) with G = H // KV."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, hd)


def _dense_attention(q, k, v, causal, q_offset=0):
    """Materialised-scores path (short sequences / smoke tests)."""
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(S)[:, None] + q_offset
        ki = jnp.arange(T)[None, :]
        logits = jnp.where(ki <= qi, logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)
    return out.reshape(B, S, KV * G, hd)


def _chunked_attention(q, k, v, causal, chunk, q_offset=0):
    """Online-softmax scan over KV blocks; O(S * chunk) memory.

    The running (m, l, acc) carry is pinned to head-sharding: without the
    constraint GSPMD propagates the sequence-parallel residual sharding
    into the scan carry and pays a full resharding copy per KV block
    (hillclimb LM-2 iteration 5).
    """
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    assert T % chunk == 0, (T, chunk)
    n_blocks = T // chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = q.astype(jnp.float32) * scale
    qf = shard_constraint(qf, ("batch", None, None, "tp", None))
    kb = k.reshape(B, n_blocks, chunk, KV, hd)
    vb = v.reshape(B, n_blocks, chunk, KV, hd)
    qi = jnp.arange(S)[:, None] + q_offset

    def pin(t):
        """(B, KV, G, S[, hd]) carries: shard the G (q-head) axis."""
        return shard_constraint(
            t, ("batch", None, "tp", None) + (None,) * (t.ndim - 4))

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, j = blk
        logits = jnp.einsum("bskgh,btkh->bkgst", qf,
                            kc.astype(jnp.float32))     # (B,KV,G,S,chunk)
        if causal:
            ki = j * chunk + jnp.arange(chunk)[None, :]
            logits = jnp.where(ki <= qi, logits, _NEG)
        m_new = pin(jnp.maximum(m, logits.max(axis=-1)))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(logits - m_new[..., None])
        l_new = pin(l * alpha + pexp.sum(axis=-1))
        acc_new = pin(acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", pexp, vc.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = pin(jnp.full((B, KV, G, S), _NEG, jnp.float32))
    l0 = pin(jnp.zeros((B, KV, G, S), jnp.float32))
    a0 = pin(jnp.zeros((B, KV, G, S, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kb.swapaxes(0, 1), vb.swapaxes(0, 1),
         jnp.arange(n_blocks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4)                   # (B,S,KV,G,hd)
    return out.reshape(B, S, KV * G, hd).astype(v.dtype)


def attention_train(params, cfg, x, positions, *, causal=True,
                    xkv=None, kv_positions=None, dtype=jnp.bfloat16,
                    return_kv: bool = False):
    """Full-sequence (self- or cross-) attention.

    ``return_kv=True`` also returns the (k, v) tensors for cache seeding
    (prefill path / whisper cross-attention precompute).
    """
    if xkv is None:
        xkv, kv_positions = x, positions
    q, k, v = _qkv(params, cfg, x, xkv, positions, kv_positions, dtype)
    qg = _group(q, cfg.n_kv_heads)
    T = k.shape[1]
    if cfg.attn_chunk and T > cfg.attn_chunk:
        out = _chunked_attention(qg, k, v, causal, cfg.attn_chunk)
    else:
        out = _dense_attention(qg, k, v, causal)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    y = dense(params, "wo", out, dtype)
    if return_kv:
        return y, (k, v)
    return y


# ----------------------------------------------------------------------
# Decode path
# ----------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Cache pytree for one attention block.

    ``cfg.kv_cache_dtype == "int8"`` stores per-(token, kv-head)
    symmetrically quantised keys/values + bf16 scales: decode is
    memory-bound on exactly this cache stream (EXPERIMENTS.md §Roofline),
    so int8 halves the dominant term at ~1e-2 logit error
    (tests/test_kv_int8.py).
    """
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(sshape, jnp.bfloat16),
                "v_s": jnp.zeros(sshape, jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _kv_quant(t):
    """(B, S, KV, hd) -> int8 codes + per-(token, head) scales."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _kv_dequant(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(
        dtype)


def _decode_attend_sp(cfg, qg, k_new, v_new, cache, index, dtype):
    """Flash-decoding over the sequence-parallel cache axis.

    Without this, GSPMD all-gathers the whole per-layer KV cache before
    the chunked attention scan (2 x cache-bytes x layers of all-gather —
    86 GB/step for the mistral decode cell, §Perf serving iteration 2).
    Manual schedule: each SP shard updates its cache slice if ``index``
    falls in it, computes a *partial* softmax over its keys, and the
    partials combine with one tiny log-sum-exp ``psum``
    (B*H*hd-sized instead of cache-sized).
    Returns (attended (B,1,KV,G,hd-flat), new_cache) or None when no
    mesh/SP context is active.
    """
    from repro.dist.sharding import _CTX, logical_to_spec, valid_spec

    ctx = _CTX.get()
    if ctx is None:
        return None
    mesh, rules = ctx
    if not getattr(rules, "flash_decode", False):
        return None
    sp_axes = tuple(a for a in rules.sp if a in mesh.axis_names)
    sp_size = 1
    for a in sp_axes:
        sp_size *= mesh.shape[a]
    T = cache["k"].shape[1]
    if sp_size == 1 or T % sp_size:
        return None
    quant = cfg.kv_cache_dtype == "int8"

    B, _, KV, G, hd = qg.shape

    def pspec(shape, logical):
        return valid_spec(shape, logical_to_spec(logical, rules, mesh),
                          mesh)

    cache_spec = jax.tree.map(
        lambda l: pspec(l.shape, ("batch", "sp", None, None)), cache)
    q_spec = pspec(qg.shape, ("batch", None, None, None, None))
    kv_spec = pspec(k_new.shape, ("batch", None, None, None))

    def body(q, kn, vn, c):
        T_loc = c["k"].shape[1]
        off = jnp.int32(0)
        stride = T_loc
        for a in reversed(sp_axes):
            off = off + jax.lax.axis_index(a) * stride
            stride *= mesh.shape[a]
        # Local cache update iff index lands in this shard's range.
        li = jnp.clip(index - off, 0, T_loc - 1)
        mine = (index >= off) & (index < off + T_loc)

        def upd(buf, new):
            cur = jax.lax.dynamic_slice_in_dim(buf, li, 1, axis=1)
            src = jnp.where(mine, new.astype(buf.dtype), cur)
            return jax.lax.dynamic_update_slice_in_dim(buf, src, li,
                                                       axis=1)

        if quant:
            kq, ks = _kv_quant(kn)
            vq, vs = _kv_quant(vn)
            nc = {"k": upd(c["k"], kq), "v": upd(c["v"], vq),
                  "k_s": upd(c["k_s"], ks), "v_s": upd(c["v_s"], vs)}
            k = _kv_dequant(nc["k"], nc["k_s"], dtype)
            v = _kv_dequant(nc["v"], nc["v_s"], dtype)
        else:
            nc = {"k": upd(c["k"], kn), "v": upd(c["v"], vn)}
            k, v = nc["k"], nc["v"]

        # Partial attention over the local keys.
        scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        logits = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        ki = off + jnp.arange(T_loc)[None, :]
        logits = jnp.where(ki <= index, logits, _NEG)
        m_loc = logits.max(axis=-1)                     # (B,KV,G,1)
        # Global max via max-psum trick, then shared-exponent partials.
        m = jax.lax.pmax(m_loc, sp_axes[0]) if len(sp_axes) == 1 else \
            _pmax_all(m_loc, sp_axes)
        p = jnp.exp(logits - m[..., None])
        l_loc = p.sum(axis=-1)
        acc_loc = jnp.einsum("bkgst,btkh->bkgsh", p,
                             v.astype(jnp.float32))
        l = l_loc
        acc = acc_loc
        for a in sp_axes:
            l = jax.lax.psum(l, a)
            acc = jax.lax.psum(acc, a)
        out = (acc / jnp.maximum(l, 1e-30)[..., None])
        out = out.transpose(0, 3, 1, 2, 4)              # (B,1,KV,G,hd)
        return out.astype(dtype), nc

    wrapped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, cache_spec),
        out_specs=(q_spec, cache_spec),
        check_vma=False)
    return wrapped(qg, k_new, v_new, cache)


def _pmax_all(x, axes):
    for a in axes:
        x = jax.lax.pmax(x, a)
    return x


def attention_decode(params, cfg, x, cache, index, *, dtype=jnp.bfloat16):
    """One-token step: update cache at ``index``, attend to the prefix.

    ``x``: (B, 1, d); ``index``: scalar int32 current position.  The
    cached keys beyond ``index`` are masked, so a fixed-size cache serves
    any prefix length (the decode_32k / long_500k cells size it to
    seq_len).  Under an active sequence-parallel sharding context the
    cache read runs as flash-decoding over the SP shards
    (:func:`_decode_attend_sp`).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), index, jnp.int32)
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions, (3, B, 1))
    q, k_new, v_new = _qkv(params, cfg, x, x, positions, positions, dtype)
    qg0 = _group(q, cfg.n_kv_heads)
    sp = _decode_attend_sp(cfg, qg0, k_new, v_new, cache, index, dtype)
    if sp is not None:
        out, new_cache = sp
        out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
        return dense(params, "wo", out, dtype), new_cache
    quant = cfg.kv_cache_dtype == "int8"
    if quant:
        kq, ks = _kv_quant(k_new)
        vq, vs = _kv_quant(v_new)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], kq, index, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vq, index, axis=1),
            "k_s": jax.lax.dynamic_update_slice_in_dim(
                cache["k_s"], ks, index, axis=1),
            "v_s": jax.lax.dynamic_update_slice_in_dim(
                cache["v_s"], vs, index, axis=1),
        }
        k = _kv_dequant(new_cache["k"], new_cache["k_s"], dtype)
        v = _kv_dequant(new_cache["v"], new_cache["v_s"], dtype)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), index, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), index, axis=1)
        new_cache = {"k": k, "v": v}
    qg = _group(q, cfg.n_kv_heads)                       # (B,1,KV,G,hd)
    T = k.shape[1]
    if cfg.attn_chunk and T > cfg.attn_chunk:
        # Streamed cache read: O(chunk) live logits even for 512k caches.
        out = _chunked_attention(qg, k, v, True, cfg.attn_chunk,
                                 q_offset=index)
    else:
        out = _dense_attention(qg, k, v, True, q_offset=index)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    y = dense(params, "wo", out, dtype)
    return y, new_cache


def attention_cross_step(params, cfg, x, k, v, *, dtype=jnp.bfloat16):
    """Decode-time cross-attention against precomputed encoder (k, v)."""
    B = x.shape[0]
    q = dense(params, "wq", x, dtype).reshape(B, 1, cfg.n_heads, cfg.hd)
    q = _rope_one(q, None, cfg)
    out = _dense_attention(_group(q, cfg.n_kv_heads), k, v, causal=False)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    return dense(params, "wo", out, dtype)
