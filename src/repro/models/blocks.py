"""Residual blocks: (mixer -> [cross-attn] -> MLP/MoE) with pre-norms.

A block is described by ``kind`` ("attn" | "mamba" | "mlstm" | "slstm"),
``use_moe`` (MoE replaces the MLP) and ``cross`` (decoder blocks of
enc-dec models).  Three entry points:

* :func:`block_forward` — full sequence (train / prefill without cache)
* :func:`block_prefill` — full sequence, also returns the decode cache
* :func:`block_step`    — one token with cache

Every assigned architecture is a stack of these; the per-arch config only
chooses the pattern (``configs/*.py``).
"""

from __future__ import annotations

import jax.numpy as jnp

from .attention import (attention_cross_step, attention_decode,
                        attention_train, init_attention, init_kv_cache)
from .layers import Param, activation, apply_norm, dense, init_dense, \
    init_norm
from .moe import init_moe, moe_forward
from .ssm import (init_mamba, init_mamba_cache, init_mlstm,
                  init_mlstm_cache, init_slstm, init_slstm_cache,
                  mamba_forward, mamba_step, mlstm_forward, mlstm_step,
                  slstm_forward, slstm_step)

__all__ = ["init_block", "init_block_cache", "block_forward",
           "block_prefill", "block_step"]


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------

def init_mlp(p: Param, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.mlp_act == "swiglu":
        init_dense(p, "w_gate", d, ff, ("fsdp", "tp"))
        init_dense(p, "w_up", d, ff, ("fsdp", "tp"))
    else:
        init_dense(p, "w_in", d, ff, ("fsdp", "tp"))
    init_dense(p, "w_down", ff, d, ("tp", "fsdp"))


def mlp_forward(params, cfg, x, dtype):
    if cfg.mlp_act == "swiglu":
        h = jnp.asarray(activation("swiglu")(
            dense(params, "w_gate", x, dtype))) \
            * dense(params, "w_up", x, dtype)
    else:
        h = activation(cfg.mlp_act)(dense(params, "w_in", x, dtype))
    return dense(params, "w_down", h, dtype)


def init_block(p: Param, cfg, kind: str, use_moe: bool,
               cross: bool = False):
    init_norm(p, "ln1", cfg.d_model, cfg.norm)
    mixer = p.sub("mixer")
    if kind == "attn":
        init_attention(mixer, cfg)
    elif kind == "mamba":
        init_mamba(mixer, cfg)
    elif kind == "mlstm":
        init_mlstm(mixer, cfg)
    elif kind == "slstm":
        init_slstm(mixer, cfg)
    else:
        raise ValueError(f"unknown mixer kind {kind!r}")
    if cross:
        init_norm(p, "lnx", cfg.d_model, cfg.norm)
        init_attention(p.sub("cross"), cfg, cross=True)
    if use_moe:
        init_norm(p, "ln2", cfg.d_model, cfg.norm)
        init_moe(p.sub("moe"), cfg)
    elif cfg.d_ff:
        init_norm(p, "ln2", cfg.d_model, cfg.norm)
        init_mlp(p.sub("mlp"), cfg)


def init_block_cache(cfg, kind: str, batch: int, max_len: int,
                     cross: bool = False, enc_len: int = 0,
                     dtype=jnp.bfloat16):
    if kind == "attn":
        cache = init_kv_cache(cfg, batch, max_len, dtype)
    elif kind == "mamba":
        cache = init_mamba_cache(cfg, batch)
    elif kind == "mlstm":
        cache = init_mlstm_cache(cfg, batch)
    elif kind == "slstm":
        cache = init_slstm_cache(cfg, batch)
    else:
        raise ValueError(kind)
    if cross:
        shape = (batch, enc_len, cfg.n_kv_heads, cfg.hd)
        cache = dict(cache)
        cache["cross_k"] = jnp.zeros(shape, dtype)
        cache["cross_v"] = jnp.zeros(shape, dtype)
    return cache


# ----------------------------------------------------------------------
# Forward paths
# ----------------------------------------------------------------------

def _ffn_part(params, cfg, x, use_moe, moe_impl, dtype):
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        h = apply_norm(params, "ln2", x, cfg.norm)
        y, aux = moe_forward(params["moe"], cfg, h, impl=moe_impl,
                             dtype=dtype)
        x = x + y
    elif cfg.d_ff:
        h = apply_norm(params, "ln2", x, cfg.norm)
        x = x + mlp_forward(params["mlp"], cfg, h, dtype)
    return x, aux


def block_forward(params, cfg, kind: str, use_moe: bool, x, positions, *,
                  causal=True, cross=False, enc_out=None,
                  enc_positions=None, moe_impl="scatter",
                  dtype=jnp.bfloat16):
    h = apply_norm(params, "ln1", x, cfg.norm)
    m = params["mixer"]
    if kind == "attn":
        mix = attention_train(m, cfg, h, positions, causal=causal,
                              dtype=dtype)
    elif kind == "mamba":
        mix = mamba_forward(m, cfg, h, dtype=dtype)
    elif kind == "mlstm":
        mix = mlstm_forward(m, cfg, h, dtype=dtype)
    else:
        mix = slstm_forward(m, cfg, h, dtype=dtype)
    x = x + mix
    if cross:
        h = apply_norm(params, "lnx", x, cfg.norm)
        x = x + attention_train(params["cross"], cfg, h, positions,
                                causal=False, xkv=enc_out,
                                kv_positions=enc_positions, dtype=dtype)
    return _ffn_part(params, cfg, x, use_moe, moe_impl, dtype)


def block_prefill(params, cfg, kind: str, use_moe: bool, x, positions,
                  max_len: int, *, cross=False, enc_out=None,
                  enc_positions=None, moe_impl="scatter",
                  dtype=jnp.bfloat16):
    """Forward + decode-cache extraction (sequence fills ``[0, S)``)."""
    B, S = x.shape[:2]
    h = apply_norm(params, "ln1", x, cfg.norm)
    m = params["mixer"]
    if kind == "attn":
        mix, (k, v) = attention_train(m, cfg, h, positions, causal=True,
                                      dtype=dtype, return_kv=True)
        pad = ((0, 0), (0, max_len - S), (0, 0), (0, 0))
        if cfg.kv_cache_dtype == "int8":
            from .attention import _kv_quant
            kq, ks = _kv_quant(k)
            vq, vs = _kv_quant(v)
            cache = {"k": jnp.pad(kq, pad), "v": jnp.pad(vq, pad),
                     "k_s": jnp.pad(ks, pad), "v_s": jnp.pad(vs, pad)}
        else:
            cache = {"k": jnp.pad(k, pad).astype(dtype),
                     "v": jnp.pad(v, pad).astype(dtype)}
    elif kind == "mamba":
        mix, cache = mamba_forward(m, cfg, h, dtype=dtype,
                                   return_state=True)
    elif kind == "mlstm":
        mix, cache = mlstm_forward(m, cfg, h, dtype=dtype,
                                   return_state=True)
    else:
        mix, cache = slstm_forward(m, cfg, h, dtype=dtype,
                                   return_state=True)
    x = x + mix
    if cross:
        h = apply_norm(params, "lnx", x, cfg.norm)
        y, (ck, cv) = attention_train(
            params["cross"], cfg, h, positions, causal=False,
            xkv=enc_out, kv_positions=enc_positions, dtype=dtype,
            return_kv=True)
        x = x + y
        cache = dict(cache)
        cache["cross_k"] = ck.astype(dtype)
        cache["cross_v"] = cv.astype(dtype)
    x, aux = _ffn_part(params, cfg, x, use_moe, moe_impl, dtype)
    return x, cache, aux


def block_step(params, cfg, kind: str, use_moe: bool, x, cache, index, *,
               cross=False, moe_impl="scatter", dtype=jnp.bfloat16):
    """One-token decode step.  ``x``: (B, 1, d)."""
    h = apply_norm(params, "ln1", x, cfg.norm)
    m = params["mixer"]
    mix_cache = {k: v for k, v in cache.items()
                 if not k.startswith("cross_")}
    if kind == "attn":
        mix, new_cache = attention_decode(m, cfg, h, mix_cache, index,
                                          dtype=dtype)
    elif kind == "mamba":
        mix, new_cache = mamba_step(m, cfg, h, mix_cache, dtype=dtype)
    elif kind == "mlstm":
        mix, new_cache = mlstm_step(m, cfg, h, mix_cache, dtype=dtype)
    else:
        mix, new_cache = slstm_step(m, cfg, h, mix_cache, dtype=dtype)
    x = x + mix
    if cross:
        h = apply_norm(params, "lnx", x, cfg.norm)
        x = x + attention_cross_step(params["cross"], cfg, h,
                                     cache["cross_k"], cache["cross_v"],
                                     dtype=dtype)
        new_cache = dict(new_cache)
        new_cache["cross_k"] = cache["cross_k"]
        new_cache["cross_v"] = cache["cross_v"]
    x, _ = _ffn_part(params, cfg, x, use_moe, moe_impl, dtype)
    return x, new_cache
