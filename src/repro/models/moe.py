"""Mixture-of-Experts layer — gather-strategy consumer #2.

Token->expert dispatch *is* a scattered gather (the paper's Part 2 in LM
clothing): tokens are scattered into per-expert buffers, expert FFNs run
as dense batched einsums, results gather back.  Two dispatch
implementations with identical semantics:

``scatter`` (default, shape-static, scales to 384 experts)
    position-in-expert via cumsum over a (N, E) one-hot, then
    ``scatter-add`` into an ``(E, C, d)`` buffer and a ``take`` back.
    On TPU the scatter/gather HLOs cross the EP shards, which GSPMD turns
    into collectives — the dominant collective term of the MoE cells
    (EXPERIMENTS.md §Roofline) and the target of hillclimb LM-2.
``einsum``
    GShard-style dense dispatch mask ``(N, E, C)`` einsums — zero
    gather/scatter HLOs (the MoE analogue of the one-hot MXU trick).
    Memory O(N*E*C); used for small expert counts and as the semantic
    cross-check oracle in tests.

Capacity ``C = ceil(top_k * N / E * capacity_factor)``; overflow tokens
drop (standard), underflow slots compute zeros.  Router in fp32, aux
load-balance loss per Switch-Transformer.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_constraint

from .layers import Param, activation

__all__ = ["init_moe", "moe_forward", "moe_capacity"]


def init_moe(p: Param, cfg):
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    p.add("router", (d, E), (None, "ep"), scale=1.0 / math.sqrt(d))
    p.add("w_gate", (E, d, ff), ("ep", "fsdp", None),
          scale=1.0 / math.sqrt(d))
    p.add("w_up", (E, d, ff), ("ep", "fsdp", None),
          scale=1.0 / math.sqrt(d))
    p.add("w_down", (E, ff, d), ("ep", None, "fsdp"),
          scale=1.0 / math.sqrt(ff))


def moe_capacity(cfg, n_tokens: int) -> int:
    c = math.ceil(cfg.top_k * n_tokens / cfg.n_experts
                  * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)     # round up to a sublane multiple


def _route(params, cfg, xf):
    """Router logits -> (gates, idx) with renormalised top-k weights."""
    logits = xf @ params["router"].astype(jnp.float32)      # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)            # (N, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return probs, gates, idx


def _aux_loss(cfg, probs, idx):
    """Switch load-balance loss: E * sum_e f_e * P_e."""
    E = cfg.n_experts
    counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(idx.size, 1)
    P = probs.mean(axis=0)
    return E * jnp.sum(f * P)


def _expert_ffn(params, cfg, buf, dtype):
    """Batched expert FFNs.  ``buf``: (E, C, d) -> (E, C, d)."""
    act = activation("swiglu")
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dtype))
    h = act(g) * u
    h = shard_constraint(h, ("ep", None, None))
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dtype))


def moe_forward(params, cfg, x, *, impl: str = "scatter",
                dtype=jnp.bfloat16, groups: int | None = None):
    """MoE FFN.  ``x``: (B, S, d) -> ((B, S, d), aux_loss).

    ``impl="grouped"`` adds GShard-style dispatch groups sized to the
    data-parallel shard count: capacity accounting is per group, so the
    scatter into the ``(E, G, C/G, d)`` buffer never crosses the batch
    shards (hillclimb LM-2 iteration 2).  Semantics differ from
    ``scatter`` only in *which* tokens drop under overflow (per-group
    instead of global waterline), the standard GShard trade.
    """
    B, S, d = x.shape
    N = B * S
    xt = x.reshape(N, d)
    xf = xt.astype(jnp.float32)
    C = moe_capacity(cfg, N)
    E, k = cfg.n_experts, cfg.top_k

    probs, gates, idx = _route(params, cfg, xf)
    aux = _aux_loss(cfg, probs, idx)

    if impl == "grouped":
        return _moe_grouped(params, cfg, x, xt, gates, idx, C, aux,
                            dtype, groups)
    if impl == "ep":
        out = _moe_manual_ep(params, cfg, x, dtype)
        if out is not None:
            return out
        impl = "scatter"             # no mesh context -> local fallback

    if impl == "einsum":
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)   # (N, k, E)
        sel = onehot.sum(1)                                  # (N, E)
        pos = (jnp.cumsum(sel, axis=0) - sel)                # pre-count
        pos_k = jnp.einsum("nke,ne->nk", onehot, pos)        # (N, k)
        keep = pos_k < C
        slot = jax.nn.one_hot(jnp.where(keep, pos_k, C), C,
                              dtype=jnp.float32)             # (N, k, C)
        disp = jnp.einsum("nke,nkc->nec", onehot, slot)      # (N, E, C)
        buf = jnp.einsum("nec,nd->ecd", disp, xf).astype(dtype)
        out_buf = _expert_ffn(params, cfg, buf, dtype).astype(jnp.float32)
        comb = jnp.einsum("nec,nk,nke->nec", disp,
                          gates, onehot)
        y = jnp.einsum("nec,ecd->nd", comb, out_buf)
        return y.reshape(B, S, d).astype(x.dtype), aux

    if impl != "scatter":
        raise ValueError(f"unknown moe impl {impl!r}")

    # ---- scatter path -------------------------------------------------
    # Position-in-expert via sort-based ranking: O(N*k) memory.  (The
    # textbook cumsum-of-one-hot builds an (N*k, E) tensor — 4.3 GB of
    # s32 per layer for the qwen3/kimi cells, which GSPMD then
    # all-gathers; hillclimb LM-2 iteration 1 in EXPERIMENTS.md §Perf
    # replaced it with this formulation.)
    e_flat = idx.reshape(-1)                                 # (N*k,)
    nk = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)                 # (N*k,)
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(
        1, mode="drop")                                      # (E,)
    starts = jnp.cumsum(counts) - counts                     # (E,)
    pos_sorted = (jnp.arange(nk, dtype=jnp.int32)
                  - starts[e_flat[order]])
    pos_flat = jnp.zeros((nk,), jnp.int32).at[order].set(
        pos_sorted, mode="drop")
    keep = pos_flat < C
    slot = jnp.where(keep, e_flat * C + pos_flat, E * C)     # drop -> OOB
    tok = jnp.repeat(jnp.arange(N), k)

    src = xt[tok].astype(dtype) * keep[:, None].astype(dtype)
    buf = jnp.zeros((E * C + 1, d), dtype)
    buf = buf.at[slot].add(src, mode="drop")
    buf = buf[:E * C].reshape(E, C, d)
    buf = shard_constraint(buf, ("ep", None, None))

    out_buf = _expert_ffn(params, cfg, buf, dtype)

    # Combine in the compute dtype end-to-end: the fp32 variant doubles
    # the backward scatter-add collective (LM-2 iteration 1b).
    rows = out_buf.reshape(E * C, d)
    gk = (gates.reshape(-1) * keep).astype(dtype)
    got = jnp.take(rows, jnp.clip(slot, 0, E * C - 1), axis=0)
    y = (got * gk[:, None]).reshape(N, k, d)
    y = y.astype(jnp.float32).sum(1)                         # k-sum in f32
    return y.reshape(B, S, d).astype(x.dtype), aux


def _positions_in_expert(e_flat, E: int):
    """Sort-based position-in-expert ranking (O(N*k) memory)."""
    nk = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1, mode="drop")
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[e_flat[order]]
    return jnp.zeros((nk,), jnp.int32).at[order].set(
        pos_sorted, mode="drop")


def _moe_manual_ep(params, cfg, x, dtype):
    """Manual expert parallelism via shard_map (hillclimb LM-2 iter 3).

    GSPMD resolves the cross-shard dispatch scatter by replicating the
    (E, C, d) buffer and all-reducing it — tens of GB per layer for the
    qwen3/kimi cells (EXPERIMENTS.md §Perf).  The manual schedule
    exploits two facts GSPMD cannot see:

    * activations are *replicated* over the EP (model) axis, so every
      EP shard can locally scatter the tokens bound for **its** experts
      — dispatch needs zero communication;
    * the top-k combine is a sum over experts, so one bf16 ``psum`` of
      the (N_local, d) output over the EP axis finishes the job —
      ``N*d`` moved instead of ``E*C*d`` replicate+reduce.

    Per-shard capacity is ``C_local = ceil(k * N_local / E * cf)`` —
    group-local dropping, the same semantics change as GShard groups.
    Falls back to the portable scatter path when no mesh context is
    active (single-device tests).
    """
    from repro.dist.sharding import (_CTX, logical_to_spec, valid_spec)

    ctx = _CTX.get()
    if ctx is None:
        return None
    mesh, rules = ctx
    ep_axes = tuple(a for a in rules.ep if a in mesh.axis_names)
    batch_axes = tuple(a for a in rules.batch if a in mesh.axis_names)
    ep_size = 1
    for a in ep_axes:
        ep_size *= mesh.shape[a]
    if ep_size == 1 or cfg.n_experts % ep_size:
        return None

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // ep_size

    def pspec_of(shape, logical):
        return valid_spec(shape, logical_to_spec(logical, rules, mesh),
                          mesh)

    param_specs = {
        "router": pspec_of(params["router"].shape, (None, "ep")),
        "w_gate": pspec_of(params["w_gate"].shape, ("ep", "fsdp", None)),
        "w_up": pspec_of(params["w_up"].shape, ("ep", "fsdp", None)),
        "w_down": pspec_of(params["w_down"].shape, ("ep", None, "fsdp")),
    }
    x_spec = pspec_of(x.shape, ("batch", None, None))

    def fsdp_gather(w, spec, axis):
        """Materialise the fsdp-sharded param dim inside the manual
        region (the same per-layer all-gather GSPMD pays for ZeRO-3).
        PartitionSpecs trim trailing Nones, so the axis may be absent."""
        entry = spec[axis] if axis < len(spec) else None
        for a in reversed(entry if isinstance(entry, tuple)
                          else (entry,)):
            if a is not None:
                w = jax.lax.all_gather(w, a, axis=axis, tiled=True)
        return w

    def body(p, x_loc):
        Nl = x_loc.shape[0] * x_loc.shape[1]
        xt = x_loc.reshape(Nl, d)
        # Router: gather the expert dim (tiny) for a full top-k.
        router = p["router"]
        for a in reversed(ep_axes):
            router = jax.lax.all_gather(router, a, axis=1, tiled=True)
        logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        aux = _aux_loss(cfg, probs, idx)
        for a in batch_axes:
            aux = jax.lax.pmean(aux, a)

        # Local experts only: offset of this EP shard.
        off = jnp.int32(0)
        stride = E_loc
        for a in reversed(ep_axes):
            off = off + jax.lax.axis_index(a) * stride
            stride *= mesh.shape[a]
        e_flat = idx.reshape(-1)
        local = (e_flat >= off) & (e_flat < off + E_loc)
        e_loc = jnp.where(local, e_flat - off, E_loc)
        C_loc = moe_capacity(cfg, Nl)
        pos = _positions_in_expert(e_loc, E_loc + 1)
        keep = local & (pos < C_loc)
        slot = jnp.where(keep, e_loc * C_loc + pos, E_loc * C_loc)
        tok = jnp.repeat(jnp.arange(Nl), k)
        src = (xt[tok].astype(dtype)
               * keep[:, None].astype(dtype))
        buf = jnp.zeros((E_loc * C_loc + 1, d), dtype)
        buf = buf.at[slot].add(src, mode="drop")
        buf = buf[:E_loc * C_loc].reshape(E_loc, C_loc, d)

        wg = fsdp_gather(p["w_gate"], param_specs["w_gate"], 1)
        wu = fsdp_gather(p["w_up"], param_specs["w_up"], 1)
        wd = fsdp_gather(p["w_down"], param_specs["w_down"], 2)
        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dtype))
        h = jax.nn.silu(g) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd.astype(dtype))

        rows = out_buf.reshape(E_loc * C_loc, d)
        gk = (gates.reshape(-1) * keep).astype(dtype)
        got = jnp.take(rows, jnp.clip(slot, 0, E_loc * C_loc - 1),
                       axis=0)
        y = (got * gk[:, None]).reshape(Nl, k, d)
        y = y.astype(jnp.float32).sum(1)
        # One bf16 psum over the EP axis combines all experts.
        y = y.astype(dtype)
        for a in ep_axes:
            y = jax.lax.psum(y, a)
        return y.reshape(x_loc.shape).astype(x_loc.dtype), aux

    wrapped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(param_specs, x_spec),
        out_specs=(x_spec, jax.sharding.PartitionSpec()),
        check_vma=False)
    moe_params = {n: params[n] for n in param_specs}
    return wrapped(moe_params, x)


def _moe_grouped(params, cfg, x, xt, gates, idx, C, aux, dtype,
                 groups):
    """Group-local dispatch: buffer (E, G, C/G, d), G aligned to the
    batch shards so scatter/gather stay shard-local on the data axis."""
    B, S, d = x.shape
    N = B * S
    E, k = cfg.n_experts, cfg.top_k
    G = groups or 32
    G = min(G, N)
    while N % G:
        G -= 1
    Cg = max(8, -(-C // G) // 8 * 8)
    Ng = N // G

    e_g = idx.reshape(G, Ng * k)                       # group-major
    pos_g = jax.vmap(lambda e: _positions_in_expert(e, E))(e_g)
    keep = pos_g < Cg                                  # (G, Ng*k)
    # slot within (E, G, Cg) flattened buffer (+1 overflow row)
    slot = jnp.where(keep, (e_g * G + jnp.arange(G)[:, None]) * Cg
                     + pos_g, E * G * Cg)
    tok = jnp.repeat(jnp.arange(N).reshape(G, Ng), k, axis=1)

    src = (xt[tok.reshape(-1)].astype(dtype)
           * keep.reshape(-1)[:, None].astype(dtype))
    buf = jnp.zeros((E * G * Cg + 1, d), dtype)
    buf = buf.at[slot.reshape(-1)].add(src, mode="drop")
    buf = buf[:E * G * Cg].reshape(E, G * Cg, d)
    buf = shard_constraint(buf, ("ep", "fsdp", None))

    out_buf = _expert_ffn(params, cfg, buf, dtype)
    out_buf = shard_constraint(out_buf, ("ep", "fsdp", None))

    rows = out_buf.reshape(E * G * Cg, d)
    gk = (gates.reshape(G, Ng * k) * keep).astype(dtype)
    got = jnp.take(rows, jnp.clip(slot.reshape(-1), 0,
                                  E * G * Cg - 1), axis=0)
    y = (got * gk.reshape(-1)[:, None]).reshape(N, k, d)
    y = y.astype(jnp.float32).sum(1)
    return y.reshape(B, S, d).astype(x.dtype), aux
