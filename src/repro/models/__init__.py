"""Architecture zoo (populated by model.py import at the end)."""
