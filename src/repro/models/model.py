"""GenericLM: every assigned architecture from one block-pattern engine.

The model is ``embed -> scan(periods of blocks) -> norm -> unembed`` where
a *period* is the repeating block pattern from the config (dense: one attn
block; jamba: 1 attn + 7 mamba with MoE every 2nd; xlstm: mlstm/slstm
pair; ...).  Parameters of equal-kind blocks are stacked along a leading
``n_periods`` axis and the stack is driven by ``lax.scan`` — HLO size
stays flat in depth (94-layer Qwen3-MoE lowers in seconds) and remat
policy applies per period.

Entry points (all pure):

* :func:`init_model`      -> (params, logical sharding specs)
* :func:`forward`         -> logits (+ MoE aux loss)        [train_step]
* :func:`loss_fn`         -> scalar LM loss
* :func:`prefill`         -> (last-token logits, cache)     [prefill_32k]
* :func:`decode_step`     -> (logits, cache)                [serve_step]
* :func:`init_cache`      -> decode cache pytree

Enc-dec (Whisper) adds an encoder stack + cross-attention; VLM (Qwen2-VL)
prepends projected patch embeddings with M-RoPE positions.  Modality
frontends are stubs per the assignment: ``input_specs`` feeds precomputed
frame/patch features through a single linear adapter.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_constraint

from .blocks import (block_forward, block_prefill, block_step,
                     init_block, init_block_cache)
from .layers import (Param, apply_norm, dense, embed_lookup, init_dense,
                     init_embed, init_norm, make_positions_mrope, unembed)

__all__ = ["FRONTEND_DIM", "init_model", "forward", "loss_fn", "prefill",
           "decode_step", "init_cache", "build_model"]

# Stub modality frontends: precomputed features -> linear adapter.
FRONTEND_DIM = {"audio": 80, "vision": 1176}


def _dtype(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
        cfg.param_dtype]


def _moe_flags(cfg):
    assert not cfg.moe or cfg.period % cfg.moe_every == 0 \
        or cfg.moe_every % cfg.period == 0, \
        "MoE placement must be periodic within the scanned period"
    return tuple(cfg.moe_at(j) for j in range(cfg.period))


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------

def _init_stack(key, cfg, kinds, moe_flags, n_stack: int, cross: bool):
    """Init ``n_stack`` periods of blocks, params stacked on axis 0.

    ``key=None`` -> spec-only (no arrays; see layers.Param).
    """
    dtype = _dtype(cfg)

    # Specs are identical across the stack; trace once (spec-only, no
    # allocation) and prepend the (replicated) layer axis.
    probe = Param(None, dtype)
    for j, kind in enumerate(kinds):
        init_block(probe.sub(f"b{j}"), cfg, kind, moe_flags[j],
                   cross=cross)
    specs = jax.tree.map(lambda s: ("null",) + tuple(s), probe.specs,
                         is_leaf=lambda s: isinstance(s, tuple))
    if key is None:
        return probe.params, specs

    def init_one(k):
        p = Param(k, dtype)
        for j, kind in enumerate(kinds):
            sub = p.sub(f"b{j}")
            init_block(sub, cfg, kind, moe_flags[j], cross=cross)
        return p.params

    params = jax.vmap(init_one)(jax.random.split(key, n_stack))
    return params, specs


def init_model(cfg, key):
    """Returns ``(params, specs)`` pytrees (see layers.Param).

    ``key=None`` returns ``(None-leaved tree, specs)`` without touching
    device memory — the dry-run path for 1T-param configs.
    """
    spec_only = key is None
    p = Param(key, _dtype(cfg))
    init_embed(p, cfg.vocab, cfg.d_model, cfg.tie_embeddings)
    init_norm(p, "norm_f", cfg.d_model, cfg.norm)
    if cfg.frontend:
        init_dense(p, "frontend", FRONTEND_DIM[cfg.frontend],
                   cfg.d_model, ("null", "fsdp"))
    params, specs = p.done()

    kinds = cfg.block_pattern
    bp, bs = _init_stack(None if spec_only else jax.random.fold_in(key, 1),
                         cfg, kinds, _moe_flags(cfg), cfg.n_periods,
                         cross=cfg.enc_dec)
    params["blocks"], specs["blocks"] = bp, bs

    if cfg.enc_dec:
        ep, es = _init_stack(
            None if spec_only else jax.random.fold_in(key, 2), cfg,
            ("attn",), (False,), cfg.n_enc_layers, cross=False)
        params["enc_blocks"], specs["enc_blocks"] = ep, es
        pe = Param(None if spec_only else jax.random.fold_in(key, 3),
                   _dtype(cfg))
        init_norm(pe, "norm_enc", cfg.d_model, cfg.norm)
        params.update(pe.params)
        specs.update(pe.specs)
    return params, specs


def param_specs(cfg):
    """Logical sharding specs without allocating parameters."""
    return init_model(cfg, None)[1]


def abstract_params(cfg):
    """ShapeDtypeStruct tree of the parameters (no allocation)."""
    return jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0))[0])


# ----------------------------------------------------------------------
# Input embedding (+ frontends)
# ----------------------------------------------------------------------

def _sinusoid(positions, d):
    """(B, S) -> (B, S, d) fixed sinusoidal embedding (whisper-style)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0)
                    * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed_inputs(params, cfg, batch, dtype):
    """Returns (x, positions, labels, label_mask)."""
    tokens = batch["tokens"]
    B, S_txt = tokens.shape
    x = embed_lookup(params, tokens, impl=cfg.gather_impl,
                     compute_dtype=dtype)
    labels = batch.get("labels")
    if cfg.frontend == "vision" and "patches" in batch:
        patches = dense(params, "frontend", batch["patches"], dtype)
        x = jnp.concatenate([patches, x], axis=1)
        n_img = patches.shape[1]
        g = max(1, int(math.sqrt(n_img)))
        positions = make_positions_mrope(B, x.shape[1], n_img,
                                         (g, max(1, n_img // g)))
        if labels is not None:
            labels = jnp.pad(labels, ((0, 0), (n_img, 0)),
                             constant_values=-1)
    else:
        pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                               (B, x.shape[1]))
        positions = (jnp.broadcast_to(pos, (3, B, x.shape[1]))
                     if cfg.rope == "mrope" else pos)
        if cfg.rope == "none":
            x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    mask = (labels >= 0) if labels is not None else None
    x = shard_constraint(x, ("batch", "sp_act", None))
    return x, positions, labels, mask


def _encode(params, cfg, batch, dtype):
    frames = batch["frames"]
    x = dense(params, "frontend", frames, dtype)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    x = shard_constraint(x, ("batch", None, None))

    def body(h, pp):
        h, _ = block_forward(pp["b0"], cfg, "attn", False, h, pos,
                             causal=False, dtype=dtype)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(params, "norm_enc", x, cfg.norm), pos


# ----------------------------------------------------------------------
# Forward / loss
# ----------------------------------------------------------------------

def forward(params, cfg, batch, *, moe_impl="scatter", remat=True):
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    x, positions, labels, mask = _embed_inputs(params, cfg, batch, dtype)
    enc_out = enc_pos = None
    if cfg.enc_dec:
        enc_out, enc_pos = _encode(params, cfg, batch, dtype)
    kinds = cfg.block_pattern
    moe_flags = _moe_flags(cfg)

    def body(h, pp):
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(kinds):
            h, a = block_forward(pp[f"b{j}"], cfg, kind, moe_flags[j], h,
                                 positions, cross=cfg.enc_dec,
                                 enc_out=enc_out, enc_positions=enc_pos,
                                 moe_impl=moe_impl, dtype=dtype)
            aux = aux + a
        # Megatron-SP: the residual stream (and with it every scan carry
        # and remat save) rests sequence-sharded over the TP axis when
        # rules.sp_act is set (hillclimb LM-2 iteration 4).
        h = shard_constraint(h, ("batch", "sp_act", None))
        return h, aux

    scan_body = jax.checkpoint(body) if remat else body
    x, auxs = jax.lax.scan(scan_body, x, params["blocks"])
    x = apply_norm(params, "norm_f", x, cfg.norm)
    logits = unembed(params, x, cfg.tie_embeddings, dtype)
    logits = shard_constraint(logits, ("batch", None, "tp"))
    return logits, jnp.sum(auxs)


def loss_fn(params, cfg, batch, *, aux_weight=0.01, moe_impl="scatter",
            remat=True):
    logits, aux = forward(params, cfg, batch, moe_impl=moe_impl,
                          remat=remat)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:      # vlm: patch positions
        labels = jnp.pad(labels,
                         ((0, 0), (logits.shape[1] - labels.shape[1], 0)),
                         constant_values=-1)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux_weight * aux, {"lm_loss": loss, "aux_loss": aux}


# ----------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ----------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, enc_len: int = 0):
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    one = {
        f"b{j}": init_block_cache(cfg, kind, batch, max_len,
                                  cross=cfg.enc_dec, enc_len=enc_len,
                                  dtype=dtype)
        for j, kind in enumerate(cfg.block_pattern)
    }
    return {"blocks": jax.tree.map(
        lambda a: jnp.tile(a[None], (cfg.n_periods,) + (1,) * a.ndim),
        one)}


def prefill(params, cfg, batch, max_len: int, *, moe_impl="scatter"):
    """Run the prompt, return (last-position logits, filled cache)."""
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    x, positions, _, _ = _embed_inputs(params, cfg, batch, dtype)
    enc_out = enc_pos = None
    if cfg.enc_dec:
        enc_out, enc_pos = _encode(params, cfg, batch, dtype)
    kinds = cfg.block_pattern
    moe_flags = _moe_flags(cfg)

    def body(h, pp):
        caches = {}
        for j, kind in enumerate(kinds):
            h, cache, _ = block_prefill(
                pp[f"b{j}"], cfg, kind, moe_flags[j], h, positions,
                max_len, cross=cfg.enc_dec, enc_out=enc_out,
                enc_positions=enc_pos, moe_impl=moe_impl, dtype=dtype)
            caches[f"b{j}"] = cache
        return h, caches

    x, caches = jax.lax.scan(body, x, params["blocks"])
    x = apply_norm(params, "norm_f", x, cfg.norm)
    logits = unembed(params, x[:, -1:], cfg.tie_embeddings, dtype)
    return logits, {"blocks": caches}


def decode_step(params, cfg, cache, tokens, index, *,
                moe_impl="scatter"):
    """One token for the whole batch.  ``tokens``: (B, 1) int32."""
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    x = embed_lookup(params, tokens, impl=cfg.gather_impl,
                     compute_dtype=dtype)
    if cfg.rope == "none":
        pos = jnp.full(tokens.shape, index, jnp.int32)
        x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    kinds = cfg.block_pattern
    moe_flags = _moe_flags(cfg)

    def body(h, scanned):
        pp, cc = scanned
        new_cc = {}
        for j, kind in enumerate(kinds):
            h, nc = block_step(pp[f"b{j}"], cfg, kind, moe_flags[j], h,
                               cc[f"b{j}"], index, cross=cfg.enc_dec,
                               moe_impl=moe_impl, dtype=dtype)
            new_cc[f"b{j}"] = nc
        return h, new_cc

    x, new_caches = jax.lax.scan(body, x,
                                 (params["blocks"], cache["blocks"]))
    x = apply_norm(params, "norm_f", x, cfg.norm)
    logits = unembed(params, x, cfg.tie_embeddings, dtype)
    return logits, {"blocks": new_caches}


# ----------------------------------------------------------------------

class Model:
    """Thin OO facade bundling (cfg, params, specs) for launchers."""

    def __init__(self, cfg, params, specs):
        self.cfg = cfg
        self.params = params
        self.specs = specs

    def __repr__(self):
        n = self.cfg.param_count()
        return (f"Model({self.cfg.name}, {n / 1e6:.1f}M params, "
                f"family={self.cfg.family})")


def build_model(cfg, key=None) -> Model:
    key = jax.random.PRNGKey(0) if key is None else key
    params, specs = init_model(cfg, key)
    return Model(cfg, params, specs)
