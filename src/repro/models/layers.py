"""Shared layers: params-as-pytrees, norms, embeddings, RoPE variants.

Module style: plain functions.  ``init_*`` returns ``(params, specs)`` —
two parallel pytrees, the second holding per-parameter *logical* sharding
axes (see :mod:`repro.dist.sharding`).  ``apply`` functions are pure.
No framework dependency (flax/optax unavailable offline); ~600 lines of
layer code replaces them.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.gather_ops import gather as gather_rows

__all__ = [
    "Param",
    "init_dense",
    "init_norm",
    "apply_norm",
    "init_embed",
    "embed_lookup",
    "unembed",
    "rope_freqs",
    "apply_rope",
    "make_positions_mrope",
    "activation",
]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


class Param:
    """Helper collecting (params, specs) pairs during init.

    ``key=None`` puts it in *spec-only* mode: no arrays are created (all
    params are ``None``) but the spec tree is complete — this is how the
    dry-run derives shardings for trillion-parameter configs without
    allocating a byte.
    """

    def __init__(self, key, dtype):
        self.key = key
        self.dtype = dtype
        self.params: dict = {}
        self.specs: dict = {}

    def sub(self, name: str) -> "Param":
        if self.key is None:
            p = Param(None, self.dtype)
        else:
            self.key, sub = jax.random.split(self.key)
            p = Param(sub, self.dtype)
        self.params[name] = p.params
        self.specs[name] = p.specs
        return p

    def add(self, name: str, shape, logical_axes, *, scale=None,
            init="normal"):
        self.specs[name] = tuple(logical_axes)
        if self.key is None:
            self.params[name] = None
            return None
        self.key, sub = jax.random.split(self.key)
        if init == "zeros":
            val = jnp.zeros(shape, dtype=self.dtype)
        elif init == "ones":
            val = jnp.ones(shape, dtype=self.dtype)
        else:
            if scale is None:
                scale = 1.0 / math.sqrt(shape[0])
            val = (jax.random.normal(sub, shape, jnp.float32)
                   * scale).astype(self.dtype)
        self.params[name] = val
        return val

    def done(self):
        return self.params, self.specs


# ----------------------------------------------------------------------
# Dense / norms
# ----------------------------------------------------------------------

def init_dense(p: Param, name: str, d_in: int, d_out: int, logical_axes,
               bias: bool = False):
    p.add(name, (d_in, d_out), logical_axes)
    if bias:
        p.add(name + "_b", (d_out,), (logical_axes[-1],), init="zeros")


def dense(params, name: str, x, compute_dtype=jnp.bfloat16):
    w = params[name].astype(compute_dtype)
    y = x.astype(compute_dtype) @ w
    b = params.get(name + "_b")
    if b is not None:
        y = y + b.astype(compute_dtype)
    return y


def init_norm(p: Param, name: str, d: int, kind: str = "rmsnorm"):
    p.add(name + "_scale", (d,), ("null",), init="ones")
    if kind == "layernorm":
        p.add(name + "_bias", (d,), ("null",), init="zeros")


def apply_norm(params, name: str, x, kind: str = "rmsnorm",
               eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y + params[name + "_bias"].astype(jnp.float32)
    y = y * params[name + "_scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------
# Embedding — gather-strategy consumer #1
# ----------------------------------------------------------------------

def init_embed(p: Param, vocab: int, d: int, tie: bool):
    # 1/sqrt(d) init + sqrt(d) lookup scaling keeps both the residual
    # stream and (tied) logits at unit scale.
    p.add("embed", (vocab, d), ("tp", "fsdp"), scale=1.0 / math.sqrt(d))
    if not tie:
        p.add("unembed", (d, vocab), ("fsdp", "tp"))


def embed_lookup(params, tokens, impl: str = "take",
                 compute_dtype=jnp.bfloat16):
    """Token -> vector via the configured gather strategy.

    ``impl="onehot"`` routes the 150k-row vocab gathers through the MXU
    (zero gather HLOs) — the paper's technique applied to embeddings; the
    dry-run op census quantifies the trade (EXPERIMENTS.md §Perf).
    """
    table = params["embed"]
    d = table.shape[1]
    out = gather_rows(table, tokens, impl=impl)
    return out.astype(compute_dtype) * jnp.asarray(
        math.sqrt(d), compute_dtype)


def unembed(params, x, tie: bool, compute_dtype=jnp.bfloat16):
    if tie:
        w = params["embed"].astype(compute_dtype).T
    else:
        w = params["unembed"].astype(compute_dtype)
    return (x.astype(compute_dtype) @ w).astype(jnp.float32)


# ----------------------------------------------------------------------
# RoPE family: standard, 2d (ChatGLM), M-RoPE (Qwen2-VL)
# ----------------------------------------------------------------------

def rope_freqs(hd: int, theta: float, rotary_dim: int | None = None):
    rd = rotary_dim or hd
    return 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


def apply_rope(q, k, positions, hd: int, theta: float, variant: str):
    """Apply a RoPE variant to (B, S, H, hd) queries/keys.

    ``standard``: full-dim rotary on scalar positions ``(B, S)``.
    ``rope2d``: ChatGLM-style — rotary on the first half of the head dim
    only, the second half passes through.
    ``mrope``: Qwen2-VL multimodal rotary — the rotary dims are split in
    three sections fed by (t, h, w) position components
    ``positions: (3, B, S)``; for text tokens the three components are
    equal, recovering standard RoPE exactly (arXiv:2409.12191).
    ``none``/``nope``: identity (``none`` gets sinusoidal embeddings at the
    input instead — whisper; ``nope`` has no positional signal at all —
    jamba, which relies on the mamba blocks for position).
    """
    if variant in ("none", "nope"):
        return q, k
    if variant == "mrope":
        assert positions.ndim == 3, "mrope wants (3, B, S) positions"
        rd = hd
        inv = rope_freqs(hd, theta)                       # (rd/2,)
        n = inv.shape[0]
        # Section split 2:1:1 over frequency dims (t gets the low freqs).
        s1, s2 = n - 2 * (n // 4), n // 4
        sec = jnp.concatenate([
            jnp.zeros((s1,), jnp.int32),
            jnp.ones((s2,), jnp.int32),
            jnp.full((n - s1 - s2,), 2, jnp.int32)])
        pos = positions.astype(jnp.float32)               # (3, B, S)
        ang_all = pos[..., None] * inv                    # (3, B, S, rd/2)
        ang = ((sec == 0) * ang_all[0] + (sec == 1) * ang_all[1]
               + (sec == 2) * ang_all[2])                 # (B, S, rd/2)
        cos = jnp.cos(ang)[:, :, None, :].astype(q.dtype)
        sin = jnp.sin(ang)[:, :, None, :].astype(q.dtype)
        return _rotate(q, cos, sin), _rotate(k, cos, sin)

    rd = hd // 2 if variant == "rope2d" else hd
    inv = rope_freqs(hd, theta, rd)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, rd/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(q.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(q.dtype)
    if variant == "rope2d":
        q1, q2 = q[..., :rd], q[..., rd:]
        k1, k2 = k[..., :rd], k[..., rd:]
        return (jnp.concatenate([_rotate(q1, cos, sin), q2], -1),
                jnp.concatenate([_rotate(k1, cos, sin), k2], -1))
    return _rotate(q, cos, sin), _rotate(k, cos, sin)


def make_positions_mrope(batch: int, seq: int, n_patches: int = 0,
                         grid: tuple[int, int] | None = None):
    """(t, h, w) positions: a patch grid followed by text tokens."""
    t = jnp.arange(seq, dtype=jnp.int32)
    if n_patches and grid:
        gh, gw = grid
        hh = jnp.arange(n_patches) // gw
        ww = jnp.arange(n_patches) % gw
        tt = jnp.zeros((n_patches,), jnp.int32)
        t_txt = jnp.arange(seq - n_patches, dtype=jnp.int32) + 1
        t = jnp.concatenate([tt, t_txt])
        h = jnp.concatenate([hh, t_txt])
        w = jnp.concatenate([ww, t_txt])
    else:
        h = w = t
    pos = jnp.stack([t, h, w])                            # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))


# ----------------------------------------------------------------------
# Activations
# ----------------------------------------------------------------------

def activation(name: str):
    if name == "swiglu":                  # handled in mlp (two inputs)
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                   # Nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)
