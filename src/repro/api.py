"""The stable public surface of :mod:`repro`.

Nine PRs in, every caller was reaching into deep module paths
(``repro.core.backproject.reconstruct``, ``repro.dispatch.dispatcher...``)
— workable inside the repo, hostile to anyone building on it.  This
facade is the blessed import point: everything in ``__all__`` is covered
by the compatibility expectations of DESIGN.md §14, and option-bag
parameters on these entry points are keyword-only (a positional
``strategy`` stopped being accepted when this module appeared).

One-shot / sharded reconstruction::

    from repro.api import Geometry, filter_projections, reconstruct

    volume = reconstruct(filtered, matrices, geom, strategy="auto")

Streaming / serving::

    from repro.api import CTFrontDoor, ProjectionChunk

    fd = CTFrontDoor(geom, n_slots=4, policy="srsf")
    ticket = await fd.open_scan(tenant="clinic-a")
    await fd.submit(ticket, ProjectionChunk(projs, mats, angles))
    volume = await fd.result(ticket)

Anything *not* re-exported here (kernel internals, the tuner's sweep
machinery, the analysis passes) is implementation surface that may move
between releases; import it from its defining module and expect churn.
"""

from __future__ import annotations

from repro.core.backproject import reconstruct
from repro.core.filtering import filter_projections
from repro.core.geometry import Geometry
from repro.core.pipeline import reconstruct_shards, sharded_reconstruct
from repro.dispatch import (Dispatcher, ExecutionPlan, get_dispatcher,
                            set_dispatcher)
from repro.serving.ct_frontdoor import (AdmissionPolicy, Backpressure,
                                        CTFrontDoor, DeadlinePolicy,
                                        FairSharePolicy, FIFOPolicy,
                                        POLICIES, PolicyContext,
                                        ScanAborted, ScanTicket,
                                        SRSFPolicy)
from repro.streaming import (ProjectionChunk, ReconstructionEngine,
                             ScanState)
from repro.tune import TunedConfig, autotune

__all__ = [
    # one-shot + sharded reconstruction
    "Geometry",
    "filter_projections",
    "reconstruct",
    "sharded_reconstruct",
    "reconstruct_shards",
    # dispatch
    "Dispatcher",
    "ExecutionPlan",
    "get_dispatcher",
    "set_dispatcher",
    # tuning
    "TunedConfig",
    "autotune",
    # streaming engine
    "ProjectionChunk",
    "ReconstructionEngine",
    "ScanState",
    # serving tier
    "CTFrontDoor",
    "ScanTicket",
    "Backpressure",
    "ScanAborted",
    "AdmissionPolicy",
    "FIFOPolicy",
    "SRSFPolicy",
    "DeadlinePolicy",
    "FairSharePolicy",
    "PolicyContext",
    "POLICIES",
]
