"""repro: paper-reproduction kernels + the LM/CT production stack.

Importing any ``repro.*`` module routes through this package init, which
installs the JAX API compatibility shims first (`repro._compat`) so the
rest of the codebase — and the subprocess bodies the test suite spawns —
can target the modern sharding surface unconditionally.
"""

from . import _compat  # noqa: F401  (side effect: backfill jax API names)

__version__ = "0.1.0"

# The blessed public surface (defined in repro/api.py).  Forwarded
# lazily via PEP 562 so ``import repro`` stays cheap — the serving /
# dispatch / tune stacks only load when one of these names is touched.
# ``tests/test_api.py`` asserts this list matches ``repro.api.__all__``.
__all__ = [
    "Geometry",
    "filter_projections",
    "reconstruct",
    "sharded_reconstruct",
    "reconstruct_shards",
    "Dispatcher",
    "ExecutionPlan",
    "get_dispatcher",
    "set_dispatcher",
    "TunedConfig",
    "autotune",
    "ProjectionChunk",
    "ReconstructionEngine",
    "ScanState",
    "CTFrontDoor",
    "ScanTicket",
    "Backpressure",
    "ScanAborted",
    "AdmissionPolicy",
    "FIFOPolicy",
    "SRSFPolicy",
    "DeadlinePolicy",
    "FairSharePolicy",
    "PolicyContext",
    "POLICIES",
]


def __getattr__(name):
    if name in __all__:
        from . import api

        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
