"""repro: paper-reproduction kernels + the LM/CT production stack.

Importing any ``repro.*`` module routes through this package init, which
installs the JAX API compatibility shims first (`repro._compat`) so the
rest of the codebase — and the subprocess bodies the test suite spawns —
can target the modern sharding surface unconditionally.
"""

from . import _compat  # noqa: F401  (side effect: backfill jax API names)

__version__ = "0.1.0"
