"""Unified execution-plan dispatch (DESIGN.md §11).

One frozen :class:`ExecutionPlan` per resolved configuration, one
:class:`Dispatcher` that maps ``strategy="auto"`` to a plan — cache hit,
in-situ first-call selection, or a logged ``strip2`` fallback — so no
entry point carries its own resolution or option-filtering logic.
"""

from .dispatcher import (Dispatcher, get_dispatcher, insitu_candidates,
                         reset_dispatcher, set_dispatcher)
from .plan import ExecutionPlan

__all__ = ["ExecutionPlan", "Dispatcher", "insitu_candidates",
           "get_dispatcher", "set_dispatcher", "reset_dispatcher"]
