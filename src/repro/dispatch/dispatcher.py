"""Dispatcher: ``strategy="auto"`` resolved in exactly one place.

State machine per ``(GeomStatic, backend, device_kind)`` key
(DESIGN.md §11):

1. **Cache hit** — a schema-current :class:`TunedConfig` exists under
   ``.repro_tune/`` (or the in-process memo): resolution is a dict
   lookup, zero timing work.
2. **In-situ first-call selection** — no cached decision, in-situ
   enabled (the default; disable with ``REPRO_DISPATCH_INSITU=0``) and
   the caller holds a full :class:`Geometry`: time a deterministic
   top-k candidate shortlist once each on the caller's real shapes
   (one warmup + one sample per candidate through
   :func:`repro.tune.sweep.sweep_strategies`, the inductor
   ``MultiKernelCall`` idea), persist the winner through the normal
   schema-v4 cache, and log the selection.  Every later call — in this
   process or any other — is a lookup.
3. **Fallback** — selection unavailable (disabled, or only a bare
   ``GeomStatic`` in hand): one structured warning naming the key and
   the untimed ``strip2`` default, then the pre-dispatch behaviour
   bit-for-bit.

The timing problem is synthesized from the geometry by the sweep
(white noise at the mid-sweep angle); timings depend on shapes, not
image content, so first-call selection needs no caller arrays and a
streaming engine can resolve at construction time.
"""

from __future__ import annotations

import logging
import os
import time

from repro.core.backproject import (DEFAULT_PBATCH, STRATEGIES, GeomStatic)
from repro.core.geometry import Geometry
from repro.tune.cache import (_PALLAS_KEYS, DEFAULT_STRATEGY, TunedConfig,
                              cache_key, device_identity,
                              filter_strategy_opts, load_tuned,
                              store_tuned, tune_dir)
from repro.tune.space import Candidate, jnp_candidates, pallas_candidates

from .plan import ExecutionPlan

__all__ = ["Dispatcher", "insitu_candidates", "get_dispatcher",
           "set_dispatcher", "reset_dispatcher"]

logger = logging.getLogger("repro.dispatch")

#: Environment switch for first-call selection.  Unset/``1`` = enabled.
INSITU_ENV = "REPRO_DISPATCH_INSITU"

# Shortlist order for the jnp families: cheapest-likely-winner first so
# the selection loop fronts its budget on plausible candidates (scalar
# is the known-slow oracle and goes last).
_JNP_PREFERENCE = ("strip2", "gather", "strip", "onehot", "scalar")


def insitu_candidates(gs: GeomStatic, *, topk: int = 7,
                      include_pallas: bool = False) -> list[Candidate]:
    """Deterministic first-call shortlist for one geometry.

    One representative per jnp strategy family (first tile point of
    :func:`jnp_candidates` at :data:`DEFAULT_PBATCH`, preference-ordered)
    plus the bf16- and int8-wire strip2 competitors, truncated to
    ``topk``; with
    ``include_pallas`` the projection-batched kernel variants ride along
    (their own ``topk`` budget).  Purely a function of ``gs`` — two
    processes shortlist identically, so selection is reproducible.
    """
    topk = max(1, int(topk))
    by_key: dict[tuple[str, str], Candidate] = {}
    for cand in jnp_candidates(gs, pbatches=(DEFAULT_PBATCH,)):
        dtype = str(dict(cand.opts).get("strip_dtype", "float32"))
        by_key.setdefault((cand.strategy, dtype), cand)
    order = [(s, "float32") for s in _JNP_PREFERENCE]
    order.append(("strip2", "bfloat16"))
    order.append(("strip2", "int8"))
    picked = [by_key[k] for k in order if k in by_key][:topk]
    if include_pallas:
        batched = [c for c in pallas_candidates(gs,
                                                pbatches=(DEFAULT_PBATCH,))
                   if c.pbatch > 1]
        picked += batched[:topk]
    return picked


class Dispatcher:
    """Resolve execution plans; own the first-call selection policy.

    ``insitu=None`` reads :data:`INSITU_ENV` at resolve time (default
    on); ``include_pallas=None`` times kernel candidates only where
    they compile (TPU).  ``sweep_fn`` is injectable for tests — it must
    accept ``(geom, *, space, warmup, iters, min_total_s)`` and return
    a :class:`repro.tune.sweep.SweepResult`.
    """

    def __init__(self, *, dirpath=None, insitu: bool | None = None,
                 topk: int = 7, include_pallas: bool | None = None,
                 sweep_fn=None, backend: str | None = None,
                 device_kind: str | None = None):
        self.dirpath = dirpath
        self.insitu = insitu
        self.topk = int(topk)
        self.include_pallas = include_pallas
        self._sweep_fn = sweep_fn
        self.backend, self.device_kind = device_identity(backend,
                                                         device_kind)
        self._warned: set[tuple[str, str]] = set()
        self._audited: dict[tuple, bool] = {}

    # ------------------------------------------------------------------
    # Public surface
    # ------------------------------------------------------------------
    def resolve(self, geom: Geometry | GeomStatic, strategy: str = "auto",
                opts: dict | None = None, *,
                pbatch: int | None = None) -> ExecutionPlan:
        """One plan for one call site — the only ``auto`` resolver.

        Explicit strategies validate strictly and never touch the
        cache.  ``auto`` walks the hit → in-situ select → fallback
        machine documented on the module.
        """
        if strategy != "auto":
            return ExecutionPlan.explicit(strategy, opts, pbatch)
        gs, full_geom = self._split(geom)
        cfg, source = self._lookup_or_select(gs, full_geom)
        if cfg is None:
            self._warn_fallback(gs, surface="jnp")
            plan = self._fallback_plan(opts, pbatch)
        else:
            plan = ExecutionPlan.from_tuned(cfg, opts, pbatch)
        logger.debug("dispatch: key=%s via %s -> %s",
                     cache_key(gs, self.backend, self.device_kind),
                     source, plan.label)
        return plan

    def resolve_kernel(self, geom: Geometry | GeomStatic) -> dict | None:
        """Tuned Pallas kernel config for this key, or ``None``.

        The kernel entry points' ``strategy="auto"``: a hit (or in-situ
        selection) whose decision carries a kernel config returns it as
        kwargs; otherwise ``None`` — the caller's explicit tile
        parameters stand, with the same structured fallback warning as
        the jnp path when no decision exists at all.
        """
        gs, full_geom = self._split(geom)
        cfg, _source = self._lookup_or_select(gs, full_geom)
        if cfg is None:
            self._warn_fallback(gs, surface="kernel")
            return None
        if not cfg.pallas:
            return None
        return {k: cfg.pallas[k] for k in _PALLAS_KEYS if k in cfg.pallas}

    # ------------------------------------------------------------------
    # Resolution machinery
    # ------------------------------------------------------------------
    @staticmethod
    def _split(geom):
        if isinstance(geom, GeomStatic):
            return geom, None
        return GeomStatic.of(geom), geom

    def _insitu_enabled(self) -> bool:
        if self.insitu is not None:
            return bool(self.insitu)
        flag = os.environ.get(INSITU_ENV, "1").strip().lower()
        return flag not in ("0", "false", "off", "")

    def _include_pallas(self) -> bool:
        if self.include_pallas is not None:
            return bool(self.include_pallas)
        return self.backend == "tpu"

    def _lookup_or_select(self, gs, full_geom):
        cfg = load_tuned(gs, self.backend, self.device_kind, self.dirpath)
        if cfg is not None:
            if self._audit_ok(gs, cfg, full_geom):
                return cfg, "cache"
            cfg = None                 # stale decision: never replay it
        if full_geom is not None and self._insitu_enabled():
            cfg = self._select(full_geom)
            if cfg is not None:
                return cfg, "insitu"
        return None, "fallback"

    def _audit_ok(self, gs, cfg, full_geom) -> bool:
        """Re-validate a cached decision against the current planner
        before replaying it (the lint cache pass, inline).  A failing
        config produces ONE structured warning naming key, file, and
        every reason, and resolution falls through to in-situ selection
        — a stale-but-schema-valid window must never execute silently.
        """
        from repro.analysis.lint.cache_audit import audit_tuned_config

        memo_key = (cache_key(gs, self.backend, self.device_kind),
                    cfg.strategy, tuple(sorted((cfg.opts or {}).items())),
                    tuple(sorted((cfg.pallas or {}).items())),
                    full_geom is not None)
        hit = self._audited.get(memo_key)
        if hit is not None:
            return hit
        reasons = audit_tuned_config(gs, cfg, geom=full_geom)
        self._audited[memo_key] = not reasons
        if not reasons:
            return True
        key = cache_key(gs, self.backend, self.device_kind)
        if ("audit", key) not in self._warned:
            self._warned.add(("audit", key))
            from pathlib import Path

            d = Path(self.dirpath) if self.dirpath is not None \
                else tune_dir()
            logger.warning(
                "dispatch: cached decision for key=%s (file %s) fails "
                "the current planner and will not be replayed: %s — "
                "falling back to in-situ selection; delete the file or "
                "re-run repro.tune.autotune to refresh it",
                key, d / f"{key}.json", "; ".join(reasons))
        return False

    def _select(self, geom: Geometry) -> TunedConfig | None:
        """First-call selection: time the shortlist once, persist."""
        gs = GeomStatic.of(geom)
        key = cache_key(gs, self.backend, self.device_kind)
        space = insitu_candidates(gs, topk=self.topk,
                                  include_pallas=self._include_pallas())
        if not space:
            return None
        sweep = self._sweep_fn
        if sweep is None:
            from repro.tune.sweep import sweep_strategies

            sweep = sweep_strategies
        t0 = time.perf_counter()
        res = sweep(geom, space=space, warmup=1, iters=1, min_total_s=0.0)
        elapsed_ms = (time.perf_counter() - t0) * 1e3
        best = res.best(STRATEGIES)
        if best is None:
            logger.warning(
                "dispatch: in-situ selection for key=%s timed no valid "
                "jnp candidate (skipped: %s); falling back", key,
                res.skipped)
            return None
        best_pallas = res.best(("pallas",))
        cfg = TunedConfig(
            strategy=best.strategy, opts=dict(best.opts),
            backend=self.backend, device_kind=self.device_kind,
            us_per_call=best.us_per_call,
            pallas=dict(best_pallas.opts) if best_pallas else None,
            pallas_us=best_pallas.us_per_call if best_pallas else None,
            timings=[t.as_dict() for t in res.timings])
        path = store_tuned(gs, cfg, self.dirpath)
        logger.info(
            "dispatch: in-situ selection key=%s candidates=%d skipped=%d "
            "elapsed_ms=%.0f winner=%s us_per_proj=%.1f kernel=%s "
            "persisted=%s", key, len(res.timings), len(res.skipped),
            elapsed_ms, best.label, best.us_per_call,
            best_pallas.label if best_pallas else None, path)
        return cfg

    def _fallback_plan(self, opts, pbatch) -> ExecutionPlan:
        filtered = filter_strategy_opts(DEFAULT_STRATEGY, opts,
                                        context="dispatch")
        if pbatch is None:
            pbatch = int(filtered.pop("pbatch", DEFAULT_PBATCH))
        else:
            filtered.pop("pbatch", None)
        return ExecutionPlan(strategy=DEFAULT_STRATEGY,
                             opts=tuple(sorted(filtered.items())),
                             pbatch=max(1, int(pbatch)))

    def _warn_fallback(self, gs, *, surface: str) -> None:
        """Satellite: the silent-fallback UX.  One structured warning
        per (surface, key) per dispatcher, naming the key, the tune
        dir consulted, and the untimed default taken."""
        key = cache_key(gs, self.backend, self.device_kind)
        if (surface, key) in self._warned:
            return
        self._warned.add((surface, key))
        d = self.dirpath if self.dirpath is not None else tune_dir()
        default = (f"strategy={DEFAULT_STRATEGY!r}" if surface == "jnp"
                   else "the caller's explicit kernel parameters")
        logger.warning(
            "dispatch: no tuned decision for key=%s under %s and "
            "in-situ selection is unavailable (%s=0, or no full "
            "Geometry at the call site); falling back to untimed "
            "default %s — run repro.tune.autotune or enable in-situ "
            "selection to replace this guess with a measured winner",
            key, d, INSITU_ENV, default)


# ----------------------------------------------------------------------
# Process-wide dispatcher
# ----------------------------------------------------------------------

_DISPATCHER: Dispatcher | None = None


def get_dispatcher() -> Dispatcher:
    """The process-wide dispatcher (created lazily with defaults)."""
    global _DISPATCHER
    if _DISPATCHER is None:
        _DISPATCHER = Dispatcher()
    return _DISPATCHER


def set_dispatcher(d: Dispatcher | None) -> Dispatcher | None:
    """Swap the process-wide dispatcher; returns the previous one."""
    global _DISPATCHER
    old = _DISPATCHER
    _DISPATCHER = d
    return old


def reset_dispatcher() -> None:
    """Drop the process-wide dispatcher (tests; tune-dir swaps)."""
    set_dispatcher(None)
