"""ExecutionPlan: the one frozen description of how a reconstruction runs.

Every entry point used to thread ``(strategy, opts_tuple, pbatch)`` —
plus, on the kernel path, a second private tile-option resolution —
through its own jit static arguments.  The plan collapses that surface
into a single hashable object (DESIGN.md §11): the resolved jnp strategy
and its sample options, the projection batch depth, the tuned Pallas
kernel config when one exists, and whether the kernel beat the jnp nest
when both were measured.  Two plans that execute the same computation
compare equal, so jit compile caches key correctly no matter whether a
plan came from an explicit strategy, a cache hit, or an in-situ
selection.
"""

from __future__ import annotations

from typing import NamedTuple

from repro.core.backproject import DEFAULT_PBATCH, STRATEGIES
from repro.tune.cache import (_PALLAS_KEYS, _STRATEGY_KEYS,
                              DEFAULT_STRATEGY, TunedConfig,
                              filter_strategy_opts)

__all__ = ["ExecutionPlan"]


class ExecutionPlan(NamedTuple):
    """Frozen, hashable resolution of one reconstruction configuration.

    Fields:

    * ``strategy`` — a concrete jnp strategy (one of
      :data:`repro.core.backproject.STRATEGIES`; never ``"auto"``).
    * ``opts`` — sorted ``(key, value)`` tuple of the strategy's
      ``sample_*`` options (``pbatch`` lives in its own field).
    * ``pbatch`` — projections folded per volume pass (DESIGN.md §7).
    * ``pallas`` — sorted ``(key, value)`` tuple of the tuned Pallas
      kernel config (:data:`repro.tune.cache._PALLAS_KEYS` subset), or
      ``None`` when the key has no tuned kernel decision.
    * ``use_pallas`` — True when the tuned evidence says the kernel
      beat the best jnp strategy (``pallas_us < us_per_call``); batch
      consumers that can run either body (the streaming fold) switch on
      this.

    Provenance (cache hit vs in-situ selection vs fallback) is
    deliberately *not* a field: identical configurations must hash
    equal so they share one compiled executable.  The dispatcher logs
    where a plan came from instead.
    """

    strategy: str
    opts: tuple = ()
    pbatch: int = DEFAULT_PBATCH
    pallas: tuple | None = None
    use_pallas: bool = False

    # ------------------------------------------------------------------
    # Constructors
    # ------------------------------------------------------------------
    @classmethod
    def explicit(cls, strategy: str, opts: dict | None = None,
                 pbatch: int | None = None) -> "ExecutionPlan":
        """Plan for an explicitly named strategy — strict validation.

        Unknown option keys raise; known-but-inapplicable ones raise
        too (the caller named the strategy, so a mismatched option is a
        bug, not a cache artefact).  ``pbatch`` may ride in ``opts``.
        """
        if strategy not in STRATEGIES:
            raise ValueError(
                f"unknown strategy {strategy!r}; want one of {STRATEGIES} "
                f"(or 'auto', resolved via repro.dispatch.Dispatcher)")
        opts = dict(opts or {})
        if pbatch is None:
            pbatch = int(opts.pop("pbatch", DEFAULT_PBATCH))
        else:
            opts.pop("pbatch", None)
        opts = filter_strategy_opts(strategy, opts, strict=True,
                                    context=f"strategy={strategy!r}")
        opts.pop("pbatch", None)
        if "strip_dtype" in opts:
            # Loud on typos at plan construction, before any tracing —
            # the same wire-dtype table every sampler resolves through.
            from repro.core.backproject import strip_wire_dtype

            strip_wire_dtype(str(opts["strip_dtype"]))
        return cls(strategy=strategy, opts=tuple(sorted(opts.items())),
                   pbatch=max(1, int(pbatch)))

    @classmethod
    def from_tuned(cls, cfg: TunedConfig, caller_opts: dict | None = None,
                   pbatch: int | None = None) -> "ExecutionPlan":
        """Plan from a cached :class:`TunedConfig` + caller overrides.

        Caller options override tuned ones per key; options the tuned
        strategy does not accept are shed with a warning (the cache may
        have resolved a different strategy than the caller's options
        were written for), unknown keys raise.
        """
        strategy = (cfg.strategy if cfg.strategy in STRATEGIES
                    else DEFAULT_STRATEGY)
        allowed = _STRATEGY_KEYS[strategy]
        merged = {k: v for k, v in dict(cfg.opts).items() if k in allowed}
        merged.update(filter_strategy_opts(
            strategy, caller_opts, context="dispatch"))
        if pbatch is None:
            pbatch = int(merged.pop("pbatch", DEFAULT_PBATCH))
        else:
            merged.pop("pbatch", None)
        if "strip_dtype" in merged:
            # Same loud validation as ``explicit`` — a corrupt cache
            # entry must fail at plan construction, not mid-trace.
            from repro.core.backproject import strip_wire_dtype

            strip_wire_dtype(str(merged["strip_dtype"]))
        pallas = None
        if cfg.pallas:
            pallas = tuple(sorted(
                (k, cfg.pallas[k]) for k in _PALLAS_KEYS if k in cfg.pallas))
        use_pallas = bool(
            pallas and cfg.pallas_us is not None
            and cfg.us_per_call is not None
            and cfg.pallas_us < cfg.us_per_call)
        return cls(strategy=strategy, opts=tuple(sorted(merged.items())),
                   pbatch=max(1, int(pbatch)), pallas=pallas,
                   use_pallas=use_pallas)

    # ------------------------------------------------------------------
    # Consumption
    # ------------------------------------------------------------------
    def jnp_opts(self) -> dict:
        """The ``sample_*`` keyword options of the jnp strategy."""
        return dict(self.opts)

    def pallas_opts(self) -> dict | None:
        """The tuned kernel config as kwargs, or ``None`` when untuned."""
        return dict(self.pallas) if self.pallas else None

    @property
    def label(self) -> str:
        txt = ",".join(f"{k}={v}" for k, v in self.opts)
        body = f"{self.strategy}[{txt}]" if txt else self.strategy
        tail = "+pallas" if self.use_pallas else ""
        return f"{body}@p{self.pbatch}{tail}"

    def as_dict(self) -> dict:
        return {"strategy": self.strategy, "opts": dict(self.opts),
                "pbatch": self.pbatch,
                "pallas": dict(self.pallas) if self.pallas else None,
                "use_pallas": self.use_pallas}
