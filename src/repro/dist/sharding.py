"""Logical-axis sharding: names in model code, mesh axes at the edge.

Every tensor annotation in this repo is written against *logical* axis
names.  A :class:`ShardingRules` instance maps each logical name to a
tuple of mesh axis names; :func:`logical_to_spec` resolves an annotation
against a concrete mesh, silently pruning mesh axes the mesh does not
have — the same rules lower onto a 2-pod 512-chip production mesh, a
single 16x16 pod, or a 2-device CPU test mesh without touching model
code (DESIGN.md §5).

Two special logical names are always replicated: ``None`` and ``"null"``
(the latter used in spec *trees*, where ``None`` would read as an empty
pytree).

:func:`valid_spec` is the divisibility guard: any tensor dimension that
does not divide by the total size of its assigned mesh axes falls back
to replication for that dimension (GSPMD would otherwise pad; for the
dry-run memory accounting we want exact shards or none).

:func:`sharding_context` + :func:`shard_constraint` give model code a
zero-cost annotation idiom: ``shard_constraint(x, ("batch", None,
"tp"))`` is the identity outside a context and a
``jax.lax.with_sharding_constraint`` inside one, so single-device tests
run the exact same code path as the production launcher.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "logical_to_spec",
    "valid_spec",
    "sharding_context",
    "shard_constraint",
]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical axis -> mesh axes mapping (+ schedule feature flags).

    LM axes: ``batch`` (data parallel), ``fsdp`` (ZeRO-3 parameter
    sharding), ``tp`` (tensor parallel), ``ep`` (expert parallel), ``sp``
    (sequence-parallel KV cache), ``sp_act`` (Megatron-SP residual
    stream).  CT axes: ``vol`` (volume z-planes — the paper's OpenMP
    plane decomposition), ``proj`` (projection subsets).

    ``flash_decode`` is a schedule flag, not an axis: it opts decode into
    the manual flash-decoding path over the ``sp`` shards
    (:func:`repro.models.attention._decode_attend_sp`).
    """

    batch: tuple[str, ...] = ("pod", "data")
    fsdp: tuple[str, ...] = ("data",)
    tp: tuple[str, ...] = ("model",)
    ep: tuple[str, ...] = ("model",)
    sp: tuple[str, ...] = ()
    sp_act: tuple[str, ...] = ()
    vol: tuple[str, ...] = ("data",)
    proj: tuple[str, ...] = ("pod", "model")
    flash_decode: bool = False


def logical_to_spec(axes, rules: ShardingRules, mesh) -> P:
    """Resolve logical axis names to a PartitionSpec on ``mesh``.

    Mesh axes named by a rule but absent from ``mesh.axis_names`` are
    pruned (a podless mesh collapses ``("pod", "data")`` to ``"data"``);
    a rule whose axes are all pruned — or mapped to ``()`` — replicates.
    """
    names = set(mesh.axis_names)
    entries = []
    for ax in axes:
        if ax is None or ax == "null":
            entries.append(None)
            continue
        mapped = getattr(rules, ax)
        mapped = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        present = tuple(a for a in mapped if a in names)
        if not present:
            entries.append(None)
        elif len(present) == 1:
            entries.append(present[0])
        else:
            entries.append(present)
    return P(*entries)


def valid_spec(shape, spec: P, mesh) -> P:
    """Drop spec entries whose dimension does not divide the shard count.

    Each dimension sharded over mesh axes with total size ``n`` must be a
    multiple of ``n``; otherwise that dimension replicates.  Trailing
    replicated entries are trimmed so fully-replicated tails compare
    equal to shorter specs.
    """
    sizes = dict(mesh.shape)
    entries = []
    for i, dim in enumerate(shape):
        entry = spec[i] if i < len(spec) else None
        if entry is None:
            entries.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        shards = 1
        for a in axes:
            shards *= sizes[a]
        entries.append(entry if dim % shards == 0 else None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


# ----------------------------------------------------------------------
# Ambient sharding context
# ----------------------------------------------------------------------

# (mesh, rules) of the innermost active sharding_context, or None.  A
# ContextVar (not a bare module global) so nested/threaded launchers each
# see their own context; model code reads it via ``_CTX.get()``.
_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_context", default=None)


@contextlib.contextmanager
def sharding_context(mesh, rules: ShardingRules):
    """Make ``(mesh, rules)`` ambient for :func:`shard_constraint`."""
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


# Valid logical names for annotations (flash_decode is a flag, not an
# axis).  Checked even outside a context so a typo'd annotation fails in
# single-device unit tests, not at the first production launch.
_LOGICAL_AXES = frozenset(
    f.name for f in dataclasses.fields(ShardingRules)) - {"flash_decode"}


def shard_constraint(x, logical_axes):
    """Pin ``x`` to its logical sharding — no-op outside a context.

    Inside a :func:`sharding_context` this lowers to
    ``jax.lax.with_sharding_constraint`` with the resolved (and
    divisibility-guarded) spec; outside one it returns ``x`` unchanged,
    which is what keeps single-device unit tests free of mesh plumbing.
    """
    for ax in logical_axes:
        if ax is not None and ax != "null" and ax not in _LOGICAL_AXES:
            raise ValueError(f"unknown logical axis {ax!r}; want one of "
                             f"{sorted(_LOGICAL_AXES)}")
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = valid_spec(x.shape, logical_to_spec(logical_axes, rules, mesh),
                      mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
