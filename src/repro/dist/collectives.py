"""Hand-scheduled gradient all-reduce variants (shard_map interior).

Both functions run *inside* a manual-sharding region (``jax.shard_map``)
and reduce a gradient pytree over one named mesh axis.  They exist
because the default per-leaf ``psum`` has two production problems the
paper's scaling sections run into at mesh scale:

* **latency**: thousands of tiny all-reduces (one per parameter leaf)
  are latency-bound; :func:`bucketed_psum` concatenates consecutive
  leaves into ``>= min_bucket_bytes`` flat buckets first, so the
  interconnect sees a few large transfers (exact — pure reordering).
* **bandwidth**: fp32 gradients move 4 bytes/element;
  :func:`compress_psum` moves int8 codes plus one scalar scale and
  keeps the quantisation residual on-device as *error feedback*, so the
  running average of compressed reductions converges to the true mean
  (tests/test_distributed.py::test_compress_psum_error_feedback).  The
  quantise-with-residual step itself is :func:`repro.quant.quantize_ef`
  — shared with the ``strip_dtype="int8"`` detector wire.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["bucketed_psum", "compress_psum"]


def bucketed_psum(tree, axis: str, min_bucket_bytes: int = 1 << 22):
    """Exact all-reduce-sum of ``tree`` over ``axis``, few big transfers.

    Consecutive same-dtype leaves are flattened and concatenated until a
    bucket reaches ``min_bucket_bytes``, each bucket is ``psum``-ed as
    one vector, and the leaves are sliced back out.  Bit-exact per leaf:
    concatenation commutes with the elementwise sum.
    """
    leaves, treedef = jax.tree.flatten(tree)
    buckets: list[list[int]] = []
    cur: list[int] = []
    cur_bytes = 0
    cur_dtype = None
    for i, leaf in enumerate(leaves):
        if cur and (leaf.dtype != cur_dtype
                    or cur_bytes >= min_bucket_bytes):
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_dtype = leaf.dtype
        cur_bytes += leaf.size * leaf.dtype.itemsize
    if cur:
        buckets.append(cur)

    out = [None] * len(leaves)
    for bucket in buckets:
        flat = jnp.concatenate([leaves[i].reshape(-1) for i in bucket])
        summed = jax.lax.psum(flat, axis)
        offset = 0
        for i in bucket:
            n = leaves[i].size
            out[i] = summed[offset:offset + n].reshape(leaves[i].shape)
            offset += n
    return jax.tree.unflatten(treedef, out)


def compress_psum(tree, axis: str, error_tree):
    """int8-compressed all-reduce-*mean* with error feedback.

    Per leaf: add the carried residual, quantise to int8 on a shared
    symmetric grid (scale = global absmax via ``pmax``), all-gather the
    codes (the only non-scalar transfer — 1 byte/element), sum them
    locally in int32, and return the dequantised mean.  The new residual
    ``(x + e) - dequant(q)`` is returned for the caller to carry into
    the next step — the EF trick that turns a biased one-shot compressor
    into an asymptotically exact reduction (the running average of
    outputs converges to the true mean at 1/t).

    Returns ``(mean_tree, new_error_tree)``; wire bytes per element are
    1 (codes) instead of 4, plus one fp32 scale per leaf.
    """
    from repro.quant import quantize_ef

    def one(g, e):
        amax = jax.lax.pmax(
            jnp.max(jnp.abs(g.astype(jnp.float32)
                            + e.astype(jnp.float32))), axis)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        # The shared EF primitive (repro.quant): quantise g + e on the
        # symmetric grid, carry the residual forward.
        q, new_e = quantize_ef(g.astype(jnp.float32), scale,
                               error=e.astype(jnp.float32))
        # int8 moves on the wire (an all-gather of codes); the sum runs
        # locally in int32.  A psum would widen the codes to 4 bytes and
        # erase the whole point of quantising.
        codes = jax.lax.all_gather(q.astype(jnp.int8), axis)
        total = codes.astype(jnp.int32).sum(axis=0)
        mean = total.astype(jnp.float32) * scale / codes.shape[0]
        return mean.astype(g.dtype), new_e.astype(e.dtype)

    g_leaves, treedef = jax.tree.flatten(tree)
    e_leaves = jax.tree.leaves(error_tree)
    pairs = [one(g, e) for g, e in zip(g_leaves, e_leaves)]
    return (jax.tree.unflatten(treedef, [p[0] for p in pairs]),
            jax.tree.unflatten(treedef, [p[1] for p in pairs]))
