"""Distribution layer: logical-axis sharding rules + custom collectives.

One sharding vocabulary for both workloads (DESIGN.md §5): model code and
the CT reconstruction pipeline annotate tensors with *logical* axis names
(``batch``, ``fsdp``, ``tp``, ``ep``, ``sp``, ``vol``, ``proj``, ...);
:mod:`repro.dist.sharding` maps those to mesh axes, pruning whatever the
current mesh does not have.  :mod:`repro.dist.collectives` holds the
hand-scheduled all-reduce variants (bucketed exact, int8 error-feedback).
"""

from .collectives import bucketed_psum, compress_psum  # noqa: F401
from .sharding import (ShardingRules, logical_to_spec,  # noqa: F401
                       shard_constraint, sharding_context, valid_spec)

__all__ = [
    "ShardingRules",
    "logical_to_spec",
    "valid_spec",
    "sharding_context",
    "shard_constraint",
    "bucketed_psum",
    "compress_psum",
]
