"""Production mesh construction.

A function, not a module-level constant: importing this module never
touches jax device state (device count is locked at first use, and only
``dryrun.py`` sets the 512-placeholder-device XLA flag).

Production topology (TPU v5e): a pod is a 16x16 mesh of 256 chips;
``multi_pod=True`` adds a leading 2-pod axis for the 512-chip dry-run.
At real deployment the same axes scale out (``pod`` -> #pods) without
touching model code — all sharding is expressed against axis *names*
(repro.dist.sharding).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=_auto(2))
