import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape) cell.

THE two lines above must run before any other import (jax locks the
device count at first init); everything else follows.

For each cell this script:

1. builds abstract inputs (``ShapeDtypeStruct`` — nothing is allocated),
2. builds shardings from the logical-axis rules (DESIGN.md §5),
3. ``jax.jit(step).lower(...).compile()`` on the production mesh —
   16x16 single-pod and 2x16x16 multi-pod (512 placeholder devices),
4. records ``memory_analysis()`` (fits-per-device proof),
   ``cost_analysis()`` (per-device flops/bytes) and the collective bytes
   parsed from the optimised HLO into
   ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Shapes lower what the assignment dictates: ``train_4k`` a full
fwd+bwd+AdamW ``train_step``; ``prefill_32k`` the prompt pass returning
the decode cache; ``decode_32k``/``long_500k`` a one-token ``serve_step``
against a seq_len cache.

Usage::

    python -m repro.launch.dryrun --arch chatglm3-6b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.analysis.hlo import roofline_terms
from repro.analysis.hlo_module import analyze_module
from repro.configs import SHAPES, ShapeConfig
from repro.configs.registry import ARCHS, cell_supported
from repro.dist.sharding import ShardingRules, logical_to_spec, \
    sharding_context, valid_spec
from repro.launch.mesh import make_production_mesh
from repro.models.model import (FRONTEND_DIM, abstract_params, decode_step,
                                init_cache, param_specs, prefill)
from repro.training.optim import AdamWConfig, init_opt_state, \
    opt_state_specs
from repro.training.train import make_train_step

I32 = jnp.int32
F32 = jnp.float32


# ----------------------------------------------------------------------
# Per-cell policy: rules + optimizer state dtype scale with model size
# ----------------------------------------------------------------------

def pick_rules(cfg, shape: ShapeConfig, mesh,
               sp_act: bool = False) -> ShardingRules:
    batch_axes = ("pod", "data")
    fsdp = ("pod", "data") if cfg.param_count() > 1e11 else ("data",)
    sp = ("model",) if shape.is_decode else ()
    # Sequence-parallel residual only helps archs whose sequence mixing
    # is parallel (attention); a recurrent scan over a seq-sharded stream
    # crosses shards every step (xlstm: 6-44x REGRESSION — EXPERIMENTS.md
    # §Perf LM-2 iteration 6, refuted-and-scoped).
    use_sp = (sp_act and not shape.is_decode
              and "attn" in cfg.block_pattern)
    rules = ShardingRules(batch=batch_axes, fsdp=fsdp, tp=("model",),
                          ep=("model",), sp=sp,
                          sp_act=("model",) if use_sp else (),
                          flash_decode=bool(sp_act and shape.is_decode))
    shards = 1
    for a in batch_axes:
        if a in mesh.axis_names:
            shards *= mesh.shape[a]
    if shape.global_batch % shards:
        rules = dataclasses.replace(rules, batch=())
    return rules


def pick_opt(cfg) -> AdamWConfig:
    n = cfg.param_count()
    state = "int8" if n > 5e11 else ("bfloat16" if n > 1e11
                                     else "float32")
    return AdamWConfig(state_dtype=state)


# ----------------------------------------------------------------------
# Abstract inputs
# ----------------------------------------------------------------------

def input_specs(cfg, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell."""
    gb, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"tokens": sds((gb, 1), I32)}
    batch = {}
    if cfg.frontend == "vision":
        n_img = S // 4
        batch["patches"] = sds((gb, n_img, FRONTEND_DIM["vision"]),
                               jnp.bfloat16)
        batch["tokens"] = sds((gb, S - n_img), I32)
        if shape.kind == "train":
            batch["labels"] = sds((gb, S - n_img), I32)
        return batch
    if cfg.frontend == "audio":
        batch["frames"] = sds((gb, S, FRONTEND_DIM["audio"]),
                              jnp.bfloat16)
    batch["tokens"] = sds((gb, S), I32)
    if shape.kind == "train":
        batch["labels"] = sds((gb, S), I32)
    return batch


def _sharding(mesh, rules, leaf, logical_axes):
    spec = logical_to_spec(logical_axes, rules, mesh)
    return NamedSharding(mesh, valid_spec(leaf.shape, spec, mesh))


def batch_shardings(batch, mesh, rules):
    def spec_of(leaf):
        axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return _sharding(mesh, rules, leaf, axes)

    return jax.tree.map(spec_of, batch)


def _cache_logical_specs(cfg, cache):
    """Logical axes per cache leaf, keyed by block kind + leaf name."""
    kinds = {f"b{j}": k for j, k in enumerate(cfg.block_pattern)}

    def walk(blocks):
        out = {}
        for bname, leaves in blocks.items():
            kind = kinds[bname]
            sub = {}
            for lname, leaf in leaves.items():
                nd = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
                if lname in ("k", "v", "k_s", "v_s"):
                    ax = ("null", "batch", "sp", None, None)
                elif lname in ("cross_k", "cross_v"):
                    ax = ("null", "batch", None, None, None)
                elif kind == "mamba" and lname == "conv":
                    ax = ("null", "batch", None, "tp")
                elif kind == "mamba" and lname == "h":
                    ax = ("null", "batch", "tp", None)
                elif kind == "mlstm" and lname == "C":
                    ax = ("null", "batch", "tp", None, None)
                elif kind == "mlstm" and lname in ("n",):
                    ax = ("null", "batch", "tp", None)
                elif kind == "mlstm" and lname == "m":
                    ax = ("null", "batch", "tp")
                else:                       # slstm scalar-memory states
                    ax = ("null", "batch", "tp")
                assert len(ax) == nd, (bname, lname, ax, leaf.shape)
                sub[lname] = ax
            out[bname] = sub
        return out

    return {"blocks": walk(cache["blocks"])}


def cache_shardings(cfg, cache_sds, mesh, rules):
    logical = _cache_logical_specs(cfg, cache_sds)
    return jax.tree.map(
        lambda leaf, ax: _sharding(mesh, rules, leaf, ax),
        cache_sds, logical,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def param_shardings(cfg, params_sds, mesh, rules):
    specs = param_specs(cfg)
    return jax.tree.map(
        lambda leaf, ax: _sharding(mesh, rules, leaf, ax),
        params_sds, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ----------------------------------------------------------------------
# Cell lowering
# ----------------------------------------------------------------------

def lower_cell(cfg, shape: ShapeConfig, mesh, *, moe_impl="scatter",
               remat=True, accum_steps=1, sp_act=False, kv_dtype=None):
    """Returns (lowered, meta) for one cell on one mesh."""
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    if shape.is_decode and moe_impl == "ep":
        # Manual EP all-gathers the FSDP-sharded expert weights every
        # step; at decode token counts that dominates (kimi decode:
        # x 5.2 s vs 0.06 s with portable scatter).  Dispatch policy is
        # shape-dependent: EP for train/prefill, scatter for decode.
        moe_impl = "scatter"
    rules = pick_rules(cfg, shape, mesh, sp_act=sp_act)
    params_sds = abstract_params(cfg)
    ps = param_shardings(cfg, params_sds, mesh, rules)
    batch = input_specs(cfg, shape)
    bs = batch_shardings(batch, mesh, rules)

    if shape.kind == "train":
        opt_cfg = pick_opt(cfg)
        step = make_train_step(cfg, opt_cfg, moe_impl=moe_impl,
                               remat=remat, accum_steps=accum_steps)
        opt_sds = jax.eval_shape(
            functools.partial(init_opt_state, cfg=opt_cfg), params_sds)
        ospecs = opt_state_specs(param_specs(cfg), opt_cfg.state_dtype)
        os_ = jax.tree.map(
            lambda leaf, ax: _sharding(mesh, rules, leaf, ax),
            opt_sds, ospecs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        # One-shot lowering probe, not a hot path.  # lint: ok(jit-in-fn)
        jitted = jax.jit(step, in_shardings=(ps, os_, bs),
                         out_shardings=(ps, os_, None),
                         donate_argnums=(0, 1))
        with sharding_context(mesh, rules):
            lowered = jitted.lower(params_sds, opt_sds, batch)
        meta = {"step": "train_step", "opt_state": opt_cfg.state_dtype}
    elif shape.kind == "prefill":
        def pre(params, batch):
            return prefill(params, cfg, batch, max_len=shape.seq_len,
                           moe_impl=moe_impl)

        # One-shot lowering probe, not a hot path.  # lint: ok(jit-in-fn)
        jitted = jax.jit(pre, in_shardings=(ps, bs))
        with sharding_context(mesh, rules):
            lowered = jitted.lower(params_sds, batch)
        meta = {"step": "prefill_step"}
    else:
        enc_len = shape.seq_len if cfg.enc_dec else 0
        cache_sds = jax.eval_shape(
            functools.partial(init_cache, cfg, shape.global_batch,
                              shape.seq_len, enc_len))
        cs = cache_shardings(cfg, cache_sds, mesh, rules)

        def serve_step(params, cache, tokens, index):
            return decode_step(params, cfg, cache, tokens, index,
                               moe_impl=moe_impl)

        # One-shot lowering probe, not a hot path.  # lint: ok(jit-in-fn)
        jitted = jax.jit(
            serve_step,
            in_shardings=(ps, cs, bs["tokens"], None),
            out_shardings=(None, cs),
            donate_argnums=(1,))
        with sharding_context(mesh, rules):
            lowered = jitted.lower(params_sds, cache_sds, batch["tokens"],
                                   jax.ShapeDtypeStruct((), I32))
        meta = {"step": "serve_step"}
    meta["rules"] = dataclasses.asdict(rules)
    return lowered, meta


def run_cell(cfg, shape, mesh_name: str, *, out_dir=None, verbose=True,
             **kw):
    """Lower + compile + analyse one cell; returns the record dict."""
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = mesh.size
    rec = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "chips": chips,
        "model_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    ok, why = cell_supported(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return _emit(rec, out_dir, verbose)
    t0 = time.time()
    try:
        lowered, meta = lower_cell(cfg, shape, mesh, **kw)
        rec.update(meta)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            # Older jaxlib CompiledMemoryStats has no peak field; the
            # live-bytes estimate below never needed it.
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
        }
        live = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes)
        rec["memory"]["live_bytes"] = int(live)
        rec["fits_16gb_hbm"] = bool(live < 16e9)

        # Loop-weighted per-device analysis (repro.analysis.hlo_module):
        # XLA's own cost_analysis counts while bodies once, so scanned
        # layer stacks would be undercounted by their trip counts.
        hlo = compiled.as_text()
        mod = analyze_module(hlo)
        flops = mod["flops"]
        bytes_acc = mod["bytes"]
        coll = mod["collectives"]
        xla_cost = compiled.cost_analysis() or {}
        if isinstance(xla_cost, (list, tuple)):   # pre-0.5: per-computation
            xla_cost = xla_cost[0] if xla_cost else {}
        rec["cost"] = {
            "flops_per_device": flops,
            "bytes_accessed_per_device": bytes_acc,
            "collective_bytes_per_device": coll,
            "census": mod["census"],
            "xla_flops_unweighted": float(xla_cost.get("flops", 0.0)),
        }
        rec["roofline"] = roofline_terms(flops, bytes_acc, coll["total"])

        # Useful-compute ratio: MODEL_FLOPS / compiled flops (global).
        tokens = shape.global_batch * (1 if shape.is_decode
                                       else shape.seq_len)
        n_act = cfg.active_param_count()
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * n_act * tokens
        rec["model_flops"] = float(model_flops)
        global_flops = flops * chips
        rec["useful_flops_ratio"] = (
            float(model_flops / global_flops) if global_flops else None)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return _emit(rec, out_dir, verbose)


def _emit(rec, out_dir, verbose):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(
            out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"[OK]   {rec['arch']:24s} {rec['shape']:12s} "
                  f"{rec['mesh']:8s} dominant={r['dominant']:10s} "
                  f"c={r['compute_s']:.3e} m={r['memory_s']:.3e} "
                  f"x={r['collective_s']:.3e} "
                  f"live={rec['memory']['live_bytes'] / 1e9:.2f}GB "
                  f"(lower {rec.get('lower_s')}s, "
                  f"compile {rec.get('compile_s')}s)", flush=True)
        elif rec["status"] == "skipped":
            print(f"[SKIP] {rec['arch']:24s} {rec['shape']:12s} "
                  f"{rec['mesh']:8s} {rec['reason'][:60]}", flush=True)
        else:
            print(f"[ERR]  {rec['arch']:24s} {rec['shape']:12s} "
                  f"{rec['mesh']:8s} {rec['error'][:120]}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser(
        description="Multi-pod dry-run: lower+compile every (arch x shape) cell")
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--moe-impl", default="scatter",
                    choices=["scatter", "einsum", "grouped", "ep"])
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel residual stream")
    ap.add_argument("--kv-dtype", default=None, choices=["bf16", "int8"],
                    help="decode KV-cache storage dtype")
    args = ap.parse_args()

    meshes = (["pod", "multipod"] if args.mesh == "both"
              else [args.mesh])
    # Explicit --arch/--shape filters win over --all.
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else sorted(SHAPES)

    n_bad = 0
    for mesh_name in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(ARCHS[a], SHAPES[s], mesh_name,
                               out_dir=args.out,
                               accum_steps=args.accum_steps,
                               moe_impl=args.moe_impl, sp_act=args.sp,
                               kv_dtype=args.kv_dtype)
                n_bad += rec["status"] == "error"
    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
