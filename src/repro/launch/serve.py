"""Serving launcher: continuous-batching engine over synthetic traffic.

    PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b \\
        --requests 8 --slots 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models.model import init_model
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, n_slots=args.slots,
                        max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        r = Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 4 + (i % 7) * 3),
                    max_tokens=args.max_tokens,
                    temperature=0.8 if i % 2 else 0.0)
        reqs.append(r)
        eng.submit(r)
    t0 = time.time()
    ticks = eng.run_until_done()
    dt = time.time() - t0
    n = sum(len(r.out_tokens) for r in reqs)
    print(f"{len(reqs)} reqs x {args.slots} slots: {ticks} ticks, "
          f"{n} tokens, {n / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
