"""Production training launcher: mesh + shardings + FT loop.

On real hardware this is the per-host entry point (jax.distributed
initialises from the cluster env; the mesh comes from
``make_production_mesh``).  On CPU it runs the same code path over a
local mesh — which is how the launcher itself is tested
(``tests/test_launch.py``).

    PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b \\
        --reduced --steps 50 --ckpt /tmp/repro_run
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ARCHS
from repro.data.tokens import TokenDataset
from repro.dist.sharding import (ShardingRules, logical_to_spec,
                                 sharding_context, valid_spec)
from repro.ft.manager import FaultTolerantLoop, run_with_restarts
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.model import init_model, param_specs
from repro.training import AdamWConfig, init_opt_state, make_train_step


def tree_shardings(tree, spec_tree, mesh, rules):
    def one(leaf, ax):
        return NamedSharding(mesh, valid_spec(
            leaf.shape, logical_to_spec(ax, rules, mesh), mesh))

    return jax.tree.map(one, tree, spec_tree,
                        is_leaf=lambda x: hasattr(x, "shape"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config (otherwise the full config "
                         "— wants real hardware)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--moe-impl", default="scatter")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), vocab=256)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    rules = ShardingRules(batch=("pod", "data"), fsdp=("data",))
    print(f"arch={cfg.name} ({cfg.param_count() / 1e6:.1f}M params) "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps)
    ds = TokenDataset(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    specs = param_specs(cfg)

    with sharding_context(mesh, rules):
        # Built once per launch, reused every step.  # lint: ok(jit-in-fn)
        step_fn = jax.jit(make_train_step(
            cfg, opt_cfg, moe_impl=args.moe_impl, remat=True,
            accum_steps=args.accum_steps))

        def init_fn():
            params, _ = init_model(cfg, jax.random.PRNGKey(0))
            params = jax.device_put(
                params, tree_shardings(params, specs, mesh, rules))
            opt = init_opt_state(params, opt_cfg)
            return {"params": params, "opt": opt}

        def train_one(state, step):
            batch = ds.batch(jnp.int32(step))
            p, o, metrics = step_fn(state["params"], state["opt"], batch)
            return {"params": p, "opt": o}, metrics

        def make_loop():
            return FaultTolerantLoop(args.ckpt,
                                     save_every=args.save_every)

        state, step, restarts = run_with_restarts(
            make_loop, init_fn,
            lambda s, i: _logged(train_one, s, i), args.steps)
    print(f"finished at step {step} ({restarts} restarts)")


def _logged(fn, state, i):
    state, metrics = fn(state, i)
    if i % 10 == 0:
        print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.2f}", flush=True)
    return state, metrics


if __name__ == "__main__":
    main()
