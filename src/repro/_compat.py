"""Backfill newer JAX sharding API names on older jaxlib installs.

The repo is written against the modern surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``,
``jax.lax.pcast``).  Older releases (0.4.x, the pinned offline toolchain)
ship the same machinery under experimental names or not at all, so this
module *adds* the missing attributes at ``repro`` import time.  Rules:

* never override a name the installed jax already provides;
* semantic no-ops only where the old runtime genuinely needs none
  (``pcast`` exists to satisfy the 0.7 varying-manual-axes type system;
  0.4.x shard_map has no such typing, so identity is exact);
* ``check_vma`` (new name) is translated to ``check_rep`` (old name).

Keeping the translation in one place means every caller — src, tests and
the subprocess bodies tests spawn — writes current-jax code only.

Patching the ``jax`` namespace (rather than exporting shims from
``repro``) is deliberate: the test suite spawns subprocess bodies that
call ``jax.make_mesh(..., axis_types=...)`` / ``jax.shard_map`` by their
modern names, so the names must exist on ``jax`` itself.  The cost is
that other code in the same process feature-detecting jax via
``hasattr`` will see the backfilled names; the shims therefore stay
minimal and are only added, never replaced.
"""

from __future__ import annotations

import enum
import functools

import jax


def _install_shard_map():
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                  check_rep=None, **kwargs):
        if check_rep is None:
            check_rep = True if check_vma is None else check_vma
        return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=check_rep, **kwargs)

    jax.shard_map = shard_map


def _install_axis_type():
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh():
    import inspect

    sig = inspect.signature(jax.make_mesh)
    if "axis_types" in sig.parameters:
        return
    _make_mesh = jax.make_mesh

    @functools.wraps(_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
        # Pre-AxisType meshes behave like all-Auto under GSPMD; the
        # explicit/manual distinction does not exist yet, so the argument
        # carries no information on this runtime.
        del axis_types
        return _make_mesh(axis_shapes, axis_names, **kwargs)

    jax.make_mesh = make_mesh


def _install_pcast():
    if hasattr(jax.lax, "pcast"):
        return
    pvary = getattr(jax.lax, "pvary", None)
    if pvary is not None:
        # Mid-window releases have the vma type system but spell the
        # cast ``pvary``; identity would fail the varying-axes check.
        def pcast(x, axis_name, *, to=None):
            return pvary(x, axis_name) if to == "varying" else x
    else:
        def pcast(x, axis_name, *, to=None):
            # 0.4.x shard_map has no varying-manual-axes typing: every
            # value may vary implicitly, so the cast is a true no-op.
            del axis_name, to
            return x

    jax.lax.pcast = pcast


def install():
    _install_shard_map()
    _install_axis_type()
    _install_make_mesh()
    _install_pcast()


install()
