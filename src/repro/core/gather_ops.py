"""Generalised gather strategies — the paper's technique as a library.

The paper's portable lesson is that *how* you materialise a scattered
gather matters more than ISA width: hardware gather (AVX2/IMCI) can lose to
structured loads (AVX/FMA3), and on machines with strong matrix units the
interpolation/selection itself can ride the MXU.  ``repro`` exposes that
choice wherever an LM gathers:

* ``Embed`` (vocab tables up to 256000 rows in the assigned archs),
* MoE dispatch/combine (``repro.models.moe``),
* the back projection kernel itself (:mod:`repro.core.backproject`).

``gather_impl`` values:

``take``
    ``table[ids]`` — the XLA gather HLO.  On TPU this is the "hardware
    gather" analogue: correct, compact, and at the mercy of the backend's
    descriptor loop.
``onehot``
    chunked one-hot matmul on the MXU.  ``2 * V * D`` flops per token, but
    zero gather HLOs: the matrix unit plays texture unit.  Wins when the
    table is small/hot (MoE router combines, small codebooks) or when
    gathers would serialise; loses asymptotically on big-vocab tables.
    Differentiable (the transpose matmul is the scatter-add), which makes
    it the *training-safe* path where scatter performance is the concern.
``auto``
    picks ``take`` for big tables, ``onehot`` under
    :data:`ONEHOT_AUTO_MAX_ROWS` — the measured crossover from
    ``benchmarks/table4_gather_micro.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["gather", "take_gather", "onehot_gather", "ONEHOT_AUTO_MAX_ROWS"]

# Crossover measured by benchmarks/table4_gather_micro.py on the CPU
# backend; re-derived for TPU from the dry-run op census (EXPERIMENTS.md).
ONEHOT_AUTO_MAX_ROWS = 1024


def take_gather(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain XLA gather: ``table[ids]`` with clamped out-of-range ids."""
    return jnp.take(table, ids, axis=0, mode="clip")


@functools.partial(jax.jit, static_argnames=("chunk",))
def onehot_gather(table: jax.Array, ids: jax.Array,
                  chunk: int = 2048) -> jax.Array:
    """One-hot-matmul gather: no gather HLO, all flops on the MXU.

    The vocabulary axis is processed in ``chunk``-row tiles inside a
    ``fori_loop`` so HLO size and live memory stay flat in ``V``:
    ``out += onehot(ids in tile) @ table[tile]``.
    """
    V, D = table.shape
    flat = ids.reshape(-1)
    n = flat.shape[0]
    chunk = min(chunk, V)
    n_chunks = -(-V // chunk)
    pad_v = n_chunks * chunk - V
    padded = jnp.pad(table, ((0, pad_v), (0, 0))) if pad_v else table
    iota = jax.lax.broadcasted_iota(jnp.int32, (n, chunk), 1)

    def body(c, acc):
        base = c * chunk
        tile = jax.lax.dynamic_slice_in_dim(padded, base, chunk, axis=0)
        oh = (iota == (flat[:, None] - base)).astype(table.dtype)
        return acc + oh @ tile

    out = jax.lax.fori_loop(
        0, n_chunks, body,
        jnp.zeros((n, D), dtype=table.dtype))
    return out.reshape(ids.shape + (D,))


def gather(table: jax.Array, ids: jax.Array, impl: str = "auto",
           chunk: int = 2048) -> jax.Array:
    """Dispatch on ``impl`` in {take, onehot, auto}."""
    if impl == "take":
        return take_gather(table, ids)
    if impl == "onehot":
        return onehot_gather(table, ids, chunk=chunk)
    if impl == "auto":
        if table.shape[0] <= ONEHOT_AUTO_MAX_ROWS:
            return onehot_gather(table, ids, chunk=chunk)
        return take_gather(table, ids)
    raise ValueError(f"unknown gather impl {impl!r}")
