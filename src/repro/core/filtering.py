"""FDK projection preprocessing: cosine weighting + ramp filtering.

RabbitCT ships *pre-filtered* projections — the benchmark measures only the
back projection.  Because we synthesise our own raw line integrals
(:mod:`repro.core.phantom`), this module reproduces the missing
preprocessing stage of the FDK algorithm so the full pipeline
(scan -> filter -> back-project) is runnable end to end:

1. **Cosine weighting**: each ray is scaled by
   ``sdd / sqrt(sdd^2 + u^2 + v^2)`` — the cone-beam obliquity factor.
2. **Ramp filter** applied along detector rows (the ``u`` axis) using the
   band-limited Ram-Lak kernel evaluated in the spatial domain and applied
   via FFT with zero padding to the next power of two >= 2*n_u (linear, not
   circular, convolution).
3. **FDK constant**: the filtered projection is scaled by
   ``delta_theta * (sdd / (2 * sid)) * du``.  Derivation: FDK filters on
   the *virtual* detector through the isocenter (coordinates
   ``a = u / M`` with magnification ``M = sdd / sid``); rewriting the
   convolution in physical detector coordinates picks up ``M`` from the
   kernel's ``1/da^2`` homogeneity and ``1/M`` from the measure, net
   ``M``; the leading FDK ``1/2`` accounts for every ray being measured
   twice over a full ``2*pi`` sweep.  For short scans (RabbitCT's 200
   degree C-arm) the doubled wedge instead gets Parker weights
   (:func:`parker_weights`).

Everything is jittable jnp code; the filter runs on device as part of the
streamed reconstruction pipeline.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .geometry import Geometry

__all__ = ["ramlak_kernel", "cosine_weights", "parker_weights",
           "FilterPlan", "make_filter_plan", "apply_filter",
           "filter_projections"]


def ramlak_kernel(n: int, du: float) -> np.ndarray:
    """Band-limited Ram-Lak kernel ``h[-n//2 : n-n//2]`` (spatial domain).

    Standard discretisation (Kak & Slaney eq. 61):
    ``h[0] = 1/(4 du^2)``, ``h[k] = -1/(pi k du)^2`` for odd ``k``, else 0.
    """
    k = np.arange(-(n // 2), n - n // 2)
    h = np.zeros(n, dtype=np.float64)
    h[k == 0] = 1.0 / (4.0 * du * du)
    odd = (np.abs(k) % 2) == 1
    h[odd] = -1.0 / (np.pi * k[odd] * du) ** 2
    return h


def cosine_weights(geom: Geometry) -> np.ndarray:
    """Cone-beam obliquity weights, shape ``(n_v, n_u)`` (host precompute)."""
    u = (np.arange(geom.n_u) - geom.cu) * geom.du
    v = (np.arange(geom.n_v) - geom.cv) * geom.dv
    uu, vv = np.meshgrid(u, v)
    return (geom.sdd / np.sqrt(geom.sdd ** 2 + uu ** 2 + vv ** 2)).astype(
        np.float32)


def parker_weights(geom: Geometry) -> np.ndarray:
    """Parker short-scan weights, shape ``(n_proj, n_u)``.

    For a sweep of ``pi + 2*delta`` (``delta`` = half fan angle) each ray is
    measured once or twice; Parker's smooth weights make the doubled wedge
    sum to one while full-2*pi scans reduce to the constant ``pi / sweep``
    (so combined with the FDK ``1/2`` the net angular measure is correct
    for any sweep).  RabbitCT's C-arm sweeps ~200 degrees, so this is what
    makes the *real* geometry reconstruct cleanly.
    """
    # Fan angle of each detector column (on the virtual detector).
    u = (np.arange(geom.n_u) - geom.cu) * geom.du
    gamma = np.arctan2(u, geom.sdd)                       # (n_u,)
    delta = float(np.max(np.abs(gamma)))
    betas = geom.angles - geom.angles[0]                  # (n_proj,)
    sweep = float(geom.sweep)

    if sweep >= 2.0 * np.pi - 1e-9:
        return np.full((geom.n_proj, geom.n_u), 2.0 * np.pi / sweep,
                       dtype=np.float32)
    if sweep < np.pi + 2 * delta - 1e-9:
        # Not enough data for exact short-scan weighting; fall back to a
        # flat compensation so at least the DC level is right.
        return np.full((geom.n_proj, geom.n_u), 2.0 * np.pi / sweep,
                       dtype=np.float32)

    b = betas[:, None]
    g = gamma[None, :]
    w = np.ones((geom.n_proj, geom.n_u), dtype=np.float64)
    # Ramp-up wedge: 0 <= beta <= 2*(delta - gamma)
    up = b <= 2.0 * (delta - g)
    with np.errstate(invalid="ignore", divide="ignore"):
        w_up = np.sin(np.pi / 4.0 * b / (delta - g)) ** 2
    w = np.where(up, np.nan_to_num(w_up, nan=0.0), w)
    # Ramp-down wedge: pi - 2*gamma <= beta <= pi + 2*delta
    down = b >= np.pi - 2.0 * g
    with np.errstate(invalid="ignore", divide="ignore"):
        w_dn = np.sin(np.pi / 4.0 * (np.pi + 2 * delta - b)
                      / (delta + g)) ** 2
    w = np.where(down, np.nan_to_num(w_dn, nan=0.0), w)
    # Beyond the short-scan range contributes zero.
    w = np.where(b > np.pi + 2 * delta, 0.0, w)
    # Parker weights are defined against an angular measure of d_beta with
    # the FDK 1/2 removed; our filter keeps the 1/2, so scale by 2 and by
    # the ratio of nominal (2*pi) to actual coverage handled above.
    return (2.0 * w).astype(np.float32)


class FilterPlan(NamedTuple):
    """Precomputed filter state for one geometry (device-resident).

    ``parker`` is the *full* ``(n_proj, n_u)`` Parker weight table (or
    ``None`` for full scans): a projection subset selects its own rows by
    **angle index**, never by position in the subset — that positional
    guess is exactly the mis-weighting bug this plan API replaced.
    """

    pad: int                        # FFT length (power of two >= 2*n_u)
    n_u: int
    n_proj: int
    scale: float                    # FDK constant (delta * sdd/(2 sid) * du)
    hf: jnp.ndarray                 # (pad//2+1,) complex ramp spectrum
    cosw: jnp.ndarray               # (n_v, n_u) cosine weights
    parker: jnp.ndarray | None      # (n_proj, n_u) or None (no short scan)


@functools.lru_cache(maxsize=32)
def make_filter_plan(geom: Geometry,
                     short_scan: bool | None = None) -> FilterPlan:
    """Host precompute for :func:`apply_filter`, cached per geometry.

    ``short_scan`` adds the Parker weight table (default: on whenever the
    sweep is below ``2*pi``).
    """
    n_u = geom.n_u
    pad = 1
    while pad < 2 * n_u:
        pad *= 2
    h = ramlak_kernel(pad, geom.du)
    # Roll zero-lag to index 0 so FFT convolution aligns with the input.
    h = np.roll(h, -(pad // 2))
    hf = jnp.asarray(np.fft.rfft(h))                      # (pad//2+1,)
    cosw = jnp.asarray(cosine_weights(geom))
    if short_scan is None:
        short_scan = geom.sweep < 2.0 * np.pi - 1e-9
    parker = jnp.asarray(parker_weights(geom)) if short_scan else None
    delta = float(geom.sweep / geom.n_proj)
    scale = delta * (geom.sdd / (2.0 * geom.sid)) * geom.du
    return FilterPlan(pad=pad, n_u=n_u, n_proj=geom.n_proj, scale=scale,
                      hf=hf, cosw=cosw, parker=parker)


def apply_filter(projections, plan: FilterPlan, pw_rows=None,
                 dtype=jnp.float32) -> jnp.ndarray:
    """Cosine + (optional per-row Parker) + ramp filter, pure jnp.

    ``projections`` is ``(k, n_v, n_u)``; ``pw_rows`` the matching
    ``(k, n_u)`` Parker rows (already *selected by angle index*), or
    ``None`` to skip short-scan weighting.  Jittable: the streaming
    engine runs this on-device per arriving chunk, and the sharded
    pipeline runs it per rank inside ``shard_map``.
    """
    w = (jnp.asarray(projections, dtype=dtype)
         * plan.cosw).astype(jnp.float32)
    if pw_rows is not None:
        w = w * pw_rows[..., None, :]
    wf = jnp.fft.rfft(w, n=plan.pad, axis=-1)
    f = jnp.fft.irfft(wf * plan.hf, n=plan.pad, axis=-1)[..., :plan.n_u]
    return (f * plan.scale).astype(dtype)


def filter_projections(projections, geom: Geometry, dtype=jnp.float32,
                       short_scan: bool | None = None,
                       angle_indices=None) -> jnp.ndarray:
    """Apply FDK weighting + ramp filter to ``(n_proj, n_v, n_u)`` rays.

    Pure-jnp and jittable.  The FFT length is padded to the next power of
    two at least ``2 * n_u`` for linear convolution.  ``short_scan`` adds
    Parker weights (default: on whenever the sweep is below ``2*pi``).

    Parker weights are a function of the projection *angle*, so a subset
    of the stack must say which angles it holds: pass ``angle_indices``
    (an int array of indices into ``geom.angles``, one per projection; a
    scalar for a single 2-D projection).  A short-scan subset whose
    length mismatches ``geom.n_proj`` without explicit indices raises —
    the old behaviour silently handed any subset the weights of the
    *first k* angles, which is wrong for every non-prefix subset a
    streamed or ``proj``-sharded caller sends.
    """
    plan = make_filter_plan(geom, short_scan)
    projections = jnp.asarray(projections, dtype=dtype)
    single = projections.ndim == 2
    if single:
        projections = projections[None]
    k = projections.shape[0]

    pw_rows = None
    if angle_indices is not None:
        idx = jnp.atleast_1d(jnp.asarray(angle_indices, jnp.int32))
        if idx.shape != (k,):
            raise ValueError(
                f"angle_indices has shape {idx.shape}; want ({k},) — one "
                f"angle index per projection")
        if not isinstance(idx, jax.core.Tracer):
            lo, hi = int(jnp.min(idx)), int(jnp.max(idx))
            if lo < 0 or hi >= geom.n_proj:
                raise ValueError(
                    f"angle_indices must lie in [0, {geom.n_proj}); got "
                    f"range [{lo}, {hi}]")
        if plan.parker is not None:
            pw_rows = plan.parker[idx]
    elif plan.parker is not None:
        if k != geom.n_proj:
            raise ValueError(
                f"{k} projection(s) for a short-scan geometry with "
                f"n_proj={geom.n_proj}: a subset must pass angle_indices "
                f"(Parker weights depend on the projection angle; "
                f"guessing the first {k} angles silently mis-weights "
                f"every non-prefix subset).  Pass angle_indices=..., or "
                f"short_scan=False to skip Parker weighting.")
        pw_rows = plan.parker

    out = apply_filter(projections, plan, pw_rows, dtype)
    return out[0] if single else out
