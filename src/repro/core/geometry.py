"""Cone-beam CT acquisition geometry (RabbitCT conventions).

The RabbitCT framework hands the back projection implementation, per
projection image ``i``, a 3x4 homogeneous projection matrix ``A_i`` plus the
scalars ``O`` (world coordinate of voxel 0) and ``MM`` (voxel pitch in mm).
This module reconstructs that interface from first principles for a circular
C-arm trajectory so that the whole pipeline (data generation, filtering,
back projection, quality evaluation) is self-contained and exactly
consistent.

Coordinate systems
------------------
VCS  voxel coordinate system: integer indices ``(x, y, z)`` in ``[0, L)``.
WCS  world coordinate system (mm), origin at the volume centre:
     ``w = O + i * MM`` per axis with ``O = -(L - 1) / 2 * MM``.
ICS  image (detector) coordinate system: continuous pixel coordinates
     ``(ix, iy)`` with ``ix`` along detector rows (width ``n_u``) and ``iy``
     along columns (height ``n_v``).  An image is stored ``I[iy, ix]``.

The projection matrices are normalised such that the homogeneous coordinate
``w`` equals 1.0 at the isocenter; the inverse-square-law weight used by the
back projection is then simply ``1 / w**2`` (Listing 1, line 43 of the
paper).

Everything here is *host-side* precompute (numpy): the RabbitCT framework
also precomputes matrices on the host.  Device code only ever consumes the
stacked ``(n_proj, 3, 4)`` matrix array.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "Geometry",
    "default_geometry",
    "projection_matrix",
    "projection_matrices",
    "source_position",
    "detector_basis",
    "voxel_origin",
    "voxel_world_coords",
    "project_voxels",
]


@dataclasses.dataclass(frozen=True)
class Geometry:
    """Static description of one circular cone-beam acquisition.

    Attributes mirror the quantities the RabbitCT framework precomputes.
    ``n_u``/``n_v`` are detector width/height in pixels (RabbitCT: 1248x960),
    ``du``/``dv`` the pixel pitch in mm, ``sid`` the source-isocenter
    distance, ``sdd`` the source-detector distance, ``L`` the reconstruction
    volume edge length in voxels and ``voxel_mm`` the voxel pitch (``MM``).
    """

    n_u: int = 1248
    n_v: int = 960
    du: float = 0.32
    dv: float = 0.32
    sid: float = 750.0
    sdd: float = 1200.0
    L: int = 512
    voxel_mm: float = 0.5
    n_proj: int = 496
    # Total gantry sweep in radians (RabbitCT C-arm: ~200 degrees).
    sweep: float = math.radians(200.0)

    # ------------------------------------------------------------------
    @property
    def O(self) -> float:  # noqa: E743  (paper's name)
        """World coordinate of voxel index 0 (identical for x/y/z)."""
        return -(self.L - 1) / 2.0 * self.voxel_mm

    @property
    def MM(self) -> float:
        """Voxel pitch in mm (paper's name)."""
        return self.voxel_mm

    @property
    def cu(self) -> float:
        """Detector centre offset along u in pixels."""
        return (self.n_u - 1) / 2.0

    @property
    def cv(self) -> float:
        """Detector centre offset along v in pixels."""
        return (self.n_v - 1) / 2.0

    @property
    def angles(self) -> np.ndarray:
        """Projection angles in radians, shape ``(n_proj,)``."""
        return np.linspace(0.0, self.sweep, self.n_proj, endpoint=False)

    @property
    def magnification(self) -> float:
        return self.sdd / self.sid

    def scaled(self, L: int, *, n_proj: int | None = None,
               n_u: int | None = None, n_v: int | None = None) -> "Geometry":
        """Return a geometry rescaled to a different volume size.

        Field of view is preserved: the voxel pitch grows as ``L`` shrinks,
        and (unless overridden) the detector resolution shrinks
        proportionally so that the voxel->pixel beam density stays the same.
        This is how the test/benchmark suite derives laptop-sized problems
        from the medically relevant 512^3 case without changing the access
        pattern statistics that the paper's analysis depends on.
        """
        factor = self.L / L
        return dataclasses.replace(
            self,
            L=L,
            voxel_mm=self.voxel_mm * factor,
            n_u=n_u if n_u is not None else max(8, int(round(self.n_u / factor))),
            n_v=n_v if n_v is not None else max(8, int(round(self.n_v / factor))),
            du=self.du * factor if n_u is None else self.du * self.n_u / n_u,
            dv=self.dv * factor if n_v is None else self.dv * self.n_v / n_v,
            n_proj=n_proj if n_proj is not None else self.n_proj,
        )


def default_geometry(**overrides) -> Geometry:
    """The RabbitCT-like default geometry, optionally overridden."""
    return Geometry(**overrides)


# ----------------------------------------------------------------------
# Trajectory frames
# ----------------------------------------------------------------------

def source_position(geom: Geometry, theta: float | np.ndarray) -> np.ndarray:
    """X-ray source position(s) in WCS for gantry angle(s) ``theta``."""
    theta = np.asarray(theta, dtype=np.float64)
    return np.stack(
        [geom.sid * np.cos(theta), geom.sid * np.sin(theta),
         np.zeros_like(theta)], axis=-1)


def detector_basis(geom: Geometry, theta: float | np.ndarray):
    """Orthonormal detector frame for angle(s) ``theta``.

    Returns ``(e_u, e_v, e_w)`` where ``e_u`` spans detector rows, ``e_v``
    detector columns (world z) and ``e_w`` is the principal-axis unit vector
    pointing from the source towards the detector.
    """
    theta = np.asarray(theta, dtype=np.float64)
    zeros = np.zeros_like(theta)
    ones = np.ones_like(theta)
    e_u = np.stack([-np.sin(theta), np.cos(theta), zeros], axis=-1)
    e_v = np.stack([zeros, zeros, ones], axis=-1)
    e_w = np.stack([-np.cos(theta), -np.sin(theta), zeros], axis=-1)
    return e_u, e_v, e_w


def projection_matrix(geom: Geometry, theta: float) -> np.ndarray:
    """Build the normalised ``3x4`` projection matrix for one angle.

    For a world point ``X`` (homogeneous ``[X, 1]``)::

        [u', v', w]^T = A @ [X, 1]
        ix = u' / w,  iy = v' / w          # detector pixel coordinates
        weight = 1 / w**2                  # inverse-square law

    ``A`` is scaled so that ``w == 1`` at the isocenter, matching the
    RabbitCT convention (the paper calls ``w`` "an approximation of the
    distance from the X-ray source to the voxel").
    """
    e_u, e_v, e_w = detector_basis(geom, theta)
    s = source_position(geom, theta)
    f_u = geom.sdd / geom.du  # focal length in pixel units (u)
    f_v = geom.sdd / geom.dv
    # Rows of the unnormalised matrix: projective pinhole model.
    r0 = f_u * e_u + geom.cu * e_w
    r1 = f_v * e_v + geom.cv * e_w
    r2 = e_w
    R = np.stack([r0, r1, r2], axis=0)            # (3, 3)
    t = -R @ s                                     # (3,)
    A = np.concatenate([R, t[:, None]], axis=1)    # (3, 4)
    return (A / geom.sid).astype(np.float64)


def projection_matrices(geom: Geometry,
                        angles: Sequence[float] | None = None) -> np.ndarray:
    """Stacked matrices ``(n_proj, 3, 4)`` (float32, device-ready)."""
    if angles is None:
        angles = geom.angles
    mats = np.stack([projection_matrix(geom, float(t)) for t in angles])
    return mats.astype(np.float32)


# ----------------------------------------------------------------------
# Voxel coordinate helpers (Part 1 of the paper's kernel, host reference)
# ----------------------------------------------------------------------

def voxel_origin(geom: Geometry) -> float:
    return geom.O


def voxel_world_coords(geom: Geometry, idx: np.ndarray) -> np.ndarray:
    """VCS -> WCS: ``w = O + i * MM`` (Listing 1, lines 6-8)."""
    return geom.O + np.asarray(idx, dtype=np.float64) * geom.MM


def project_voxels(A: np.ndarray, wx, wy, wz):
    """Forward-project world coordinates through ``A`` (host reference).

    Returns ``(ix, iy, w)`` exactly as in Listing 1 lines 10-15.  Used by
    tests and by the clipping-mask brute-force oracle.
    """
    wx = np.asarray(wx, dtype=np.float64)
    wy = np.asarray(wy, dtype=np.float64)
    wz = np.asarray(wz, dtype=np.float64)
    u = wx * A[0, 0] + wy * A[0, 1] + wz * A[0, 2] + A[0, 3]
    v = wx * A[1, 0] + wy * A[1, 1] + wz * A[1, 2] + A[1, 3]
    w = wx * A[2, 0] + wy * A[2, 1] + wz * A[2, 2] + A[2, 3]
    return u / w, v / w, w
