"""The paper's contribution: cone-beam back projection + gather strategies.

Public surface re-exported here; see DESIGN.md for the x86->TPU mapping.
"""

from .backproject import (  # noqa: F401
    DEFAULT_PBATCH,
    STRATEGIES,
    GeomStatic,
    accumulate,
    backproject_batch,
    backproject_one,
    backproject_plane,
    backproject_plane_batch,
    contribution,
    fold_projections,
    plane_coords,
    reconstruct,
    sample_gather,
    sample_onehot,
    sample_scalar,
    sample_strip,
    sample_strip2,
)
from .clipping import (  # noqa: F401
    LinePlan,
    StripPlan,
    line_clip_conservative,
    line_clip_exact,
    pad_projection,
    plan_strips,
)
from .filtering import (  # noqa: F401
    FilterPlan,
    apply_filter,
    filter_projections,
    make_filter_plan,
    ramlak_kernel,
)
from .gather_ops import gather, onehot_gather, take_gather  # noqa: F401
from .geometry import (  # noqa: F401
    Geometry,
    default_geometry,
    projection_matrices,
    projection_matrix,
)
from .phantom import (  # noqa: F401
    Ellipsoid,
    forward_project,
    make_dataset,
    shepp_logan_3d,
    voxelize,
)
from .quality import psnr, quality_report, roi_mask  # noqa: F401
