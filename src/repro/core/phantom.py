"""Analytic 3-D ellipsoid phantom and exact cone-beam forward projector.

The RabbitCT dataset (a filtered C-arm scan of a rabbit) is not
redistributable, so the framework generates its own data: a Shepp-Logan-like
superposition of ellipsoids whose cone-beam line integrals have a closed
form.  This is strictly *stronger* than the benchmark's setup: we hold both
an exact projection dataset and an exact voxelised reference volume, so
reconstruction quality (benchmarks/quality) is measured against analytic
ground truth instead of another implementation's output.

For an ellipsoid with centre ``c``, semi-axes ``(a, b, cz)``, z-rotation
``phi`` and density ``rho``, a ray ``X(t) = S + t * d`` (``|d| = 1``)
intersects where ``|D^-1 R^T (X - c)|^2 = 1``; the chord length is
``2 sqrt(b^2 - a c0) / a`` with the usual quadratic coefficients, and the
line integral contribution is ``rho * chord``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .geometry import Geometry, detector_basis, source_position

__all__ = [
    "Ellipsoid",
    "shepp_logan_3d",
    "voxelize",
    "forward_project",
    "make_dataset",
]


@dataclasses.dataclass(frozen=True)
class Ellipsoid:
    center: tuple[float, float, float]   # WCS mm
    semi_axes: tuple[float, float, float]  # mm
    rho: float                            # density (additive)
    phi: float = 0.0                      # rotation about world z (radians)

    def rotation(self) -> np.ndarray:
        c, s = np.cos(self.phi), np.sin(self.phi)
        return np.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])


def shepp_logan_3d(extent_mm: float) -> list[Ellipsoid]:
    """A compact 3-D Shepp-Logan-flavoured phantom scaled to ``extent_mm``.

    ``extent_mm`` should be the world half-extent of the reconstruction
    volume; the phantom fills ~90% of it so every projection sees the full
    object (no truncation artefacts).
    """
    e = extent_mm

    def E(cx, cy, cz, a, b, c, rho, phi=0.0):
        return Ellipsoid((cx * e, cy * e, cz * e), (a * e, b * e, c * e),
                         rho, phi)

    return [
        E(0.0, 0.0, 0.0, 0.69, 0.92, 0.81, 1.0),
        E(0.0, -0.0184, 0.0, 0.6624, 0.874, 0.78, -0.8),
        E(0.22, 0.0, 0.0, 0.11, 0.31, 0.22, -0.2, np.radians(-18)),
        E(-0.22, 0.0, 0.0, 0.16, 0.41, 0.28, -0.2, np.radians(18)),
        E(0.0, 0.35, -0.15, 0.21, 0.25, 0.41, 0.1),
        E(0.0, 0.1, 0.25, 0.046, 0.046, 0.05, 0.1),
        E(0.0, -0.1, 0.25, 0.046, 0.046, 0.05, 0.1),
        E(-0.08, -0.605, 0.0, 0.046, 0.023, 0.05, 0.1),
        E(0.0, -0.605, 0.0, 0.023, 0.023, 0.02, 0.1),
        E(0.06, -0.605, 0.0, 0.023, 0.046, 0.02, 0.1),
    ]


# ----------------------------------------------------------------------
# Voxelisation (the quality-metric reference volume)
# ----------------------------------------------------------------------

def voxelize(geom: Geometry, ellipsoids: Sequence[Ellipsoid] | None = None,
             dtype=np.float32) -> np.ndarray:
    """Exact voxel-centre sampling of the phantom, shape ``(L, L, L)``.

    Index order is ``volume[z, y, x]`` to match the paper's loop nest
    (``VOL[z*L*L + y*L + x]``).
    """
    if ellipsoids is None:
        ellipsoids = shepp_logan_3d(-geom.O)
    L = geom.L
    coords = geom.O + np.arange(L, dtype=np.float64) * geom.MM
    zz, yy, xx = np.meshgrid(coords, coords, coords, indexing="ij")
    pts = np.stack([xx, yy, zz], axis=-1)          # (L, L, L, 3), world xyz
    vol = np.zeros((L, L, L), dtype=np.float64)
    for ell in ellipsoids:
        rel = pts - np.asarray(ell.center)
        rel = rel @ ell.rotation()                  # rotate into body frame
        q = (rel / np.asarray(ell.semi_axes)) ** 2
        vol += ell.rho * (q.sum(axis=-1) <= 1.0)
    return vol.astype(dtype)


# ----------------------------------------------------------------------
# Exact cone-beam forward projection (the synthetic scanner)
# ----------------------------------------------------------------------

def forward_project(geom: Geometry,
                    ellipsoids: Sequence[Ellipsoid] | None = None,
                    angles: np.ndarray | None = None,
                    dtype=np.float32) -> np.ndarray:
    """Closed-form line integrals; returns ``(n_proj, n_v, n_u)``.

    Per pixel we cast the ray through the pixel centre using the same
    detector frame that builds the projection matrices, so forward and back
    projection are exactly consistent (tested in
    ``tests/test_geometry.py::test_forward_back_consistency``).
    """
    if ellipsoids is None:
        ellipsoids = shepp_logan_3d(-geom.O)
    if angles is None:
        angles = geom.angles
    n_u, n_v = geom.n_u, geom.n_v
    iu = (np.arange(n_u, dtype=np.float64) - geom.cu) * geom.du
    iv = (np.arange(n_v, dtype=np.float64) - geom.cv) * geom.dv
    uu, vv = np.meshgrid(iu, iv)                   # (n_v, n_u)

    out = np.zeros((len(angles), n_v, n_u), dtype=np.float64)
    for k, theta in enumerate(angles):
        e_u, e_v, e_w = detector_basis(geom, float(theta))
        s = source_position(geom, float(theta))
        # Ray directions through every pixel centre.
        d = (uu[..., None] * e_u + vv[..., None] * e_v
             + geom.sdd * e_w)                     # (n_v, n_u, 3)
        d /= np.linalg.norm(d, axis=-1, keepdims=True)
        acc = np.zeros((n_v, n_u), dtype=np.float64)
        for ell in ellipsoids:
            R = ell.rotation()
            inv_ax = 1.0 / np.asarray(ell.semi_axes)
            p = ((s - np.asarray(ell.center)) @ R) * inv_ax     # (3,)
            q = (d @ R) * inv_ax                                # (nv,nu,3)
            a = np.sum(q * q, axis=-1)
            b = np.sum(q * p, axis=-1)
            c0 = float(p @ p) - 1.0
            disc = b * b - a * c0
            chord = 2.0 * np.sqrt(np.maximum(disc, 0.0)) / a
            acc += ell.rho * chord
        out[k] = acc
    return out.astype(dtype)


def make_dataset(geom: Geometry, seed: int = 0):
    """Convenience bundle: (projections, matrices, reference_volume).

    ``seed`` is accepted for API symmetry with the LM data pipeline; the
    phantom itself is deterministic.
    """
    from .geometry import projection_matrices

    ells = shepp_logan_3d(-geom.O)
    projections = forward_project(geom, ells)
    matrices = projection_matrices(geom)
    reference = voxelize(geom, ells)
    return projections, matrices, reference
