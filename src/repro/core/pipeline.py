"""Distributed reconstruction pipeline (shard_map over the production mesh).

Decomposition (DESIGN.md §5):

* volume z-planes are sharded over the ``data`` mesh axis — the direct
  analogue of the paper's OpenMP plane decomposition ("the voxel volume is
  segmented into voxel planes that can be processed independently");
* the projection set is sharded over the ``model`` axis (and over ``pod``
  when present): each rank back-projects its projection subset into its
  full local z-slab, then the slabs are ``psum``-reduced over the
  projection axes.  Back projection is a sum over projections, so this is
  exact.

Collectives per reconstruction: one ``psum`` of the local volume slab per
projection-sharded axis — ``(L^3 / data_shards) * 4`` bytes, the quantity
the roofline term in ``benchmarks/fig2_scaling.py`` is built from.
Projection images are small (4.8 MB at RabbitCT scale) and stay local to
their rank; nothing else moves.

Each rank's slab update runs the batch-major loop nest of
:func:`repro.core.backproject._reconstruct_batched` (DESIGN.md §7): the
local slab streams through memory ``ceil(n_proj_local / pbatch)`` times
instead of ``n_proj_local`` times — the same P× traffic cut as the
single-device path, per rank, on top of the psum structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.dist.sharding import (ShardingRules, logical_to_spec,
                                 shard_constraint, sharding_context)

from .backproject import (GeomStatic, _reconstruct_batched,
                          validate_strip_opts)
from .filtering import apply_filter, make_filter_plan
from .geometry import Geometry

__all__ = ["sharded_reconstruct", "reconstruct_shards"]


def reconstruct_shards(local_projs, local_mats, gs: GeomStatic, plan,
                       local_volume, *, z0=None):
    """Per-rank body: back-project the local projection subset.

    ``plan`` is the resolved :class:`repro.dispatch.ExecutionPlan`
    (strategy, sample options, and batch depth in one static object —
    build one with ``ExecutionPlan.explicit(...)`` or resolve via the
    dispatcher).  ``local_volume`` may be a z-slab of the full volume;
    ``z0`` is the slab's first *global* z index (default 0 — a
    full-volume or first-slab caller).  It used to be hard-coded to 0,
    so any caller handing this body a non-first slab back-projected the
    wrong planes.
    """
    if z0 is None:
        z0 = jnp.int32(0)
    return _reconstruct_batched(local_projs, local_mats, local_volume, gs,
                                plan, jnp.asarray(z0, jnp.int32))


def sharded_reconstruct(projections, matrices, geom: Geometry, mesh: Mesh,
                        *, strategy: str = "strip2",
                        volume_axis: str = "data",
                        proj_axes: tuple[str, ...] = ("model",),
                        pbatch: int | None = None,
                        prefiltered: bool = True,
                        short_scan: bool | None = None,
                        **opts):
    """Reconstruct on a device mesh.

    ``projections``: ``(n_proj, n_v, n_u)`` filtered images.  ``n_proj``
    must divide by the product of ``proj_axes`` sizes, and ``geom.L`` by
    the ``volume_axis`` size.  Returns the full ``(L, L, L)`` volume with
    sharding ``P(volume_axis)`` on z.

    ``prefiltered=False`` takes *raw* line integrals instead: each rank
    FDK-filters its own projection subset on-device inside the
    ``shard_map`` body (cosine + Parker + ramp, DESIGN.md §8) before
    back-projecting, so the preprocessing stage scales out with the
    ``proj`` axes.  Parker weights are selected by *global angle index*
    — the full ``(n_proj, n_u)`` weight table is sharded along the
    projection axis exactly like the projections, so every rank weights
    its subset by the angles it actually holds.  (Filtering a non-prefix
    subset used to be impossible without silent mis-weighting:
    ``filter_projections`` handed any subset the first-k-angles
    weights.)

    ``strategy="auto"`` resolves through the process dispatcher
    (:mod:`repro.dispatch`) exactly like
    :func:`repro.core.backproject.reconstruct` — resolution (including
    the tuned ``pbatch``) happens here, host-side, before the
    ``shard_map`` closure is built, so every rank runs one identical
    plan.
    """
    from repro.dispatch import get_dispatcher

    gs = GeomStatic.of(geom)
    plan = get_dispatcher().resolve(geom, strategy, opts, pbatch=pbatch)
    validate_strip_opts(geom, matrices, plan.strategy, plan.jnp_opts())
    proj_shards = 1
    for ax in proj_axes:
        proj_shards *= mesh.shape[ax]
    z_shards = mesh.shape[volume_axis]
    if projections.shape[0] % proj_shards:
        raise ValueError(
            f"n_proj={projections.shape[0]} not divisible by "
            f"projection shards {proj_shards}")
    if gs.L % z_shards:
        raise ValueError(f"L={gs.L} not divisible by {z_shards} z-shards")

    fplan = None
    pw_full = None
    if not prefiltered:
        if projections.shape[0] != geom.n_proj:
            raise ValueError(
                f"prefiltered=False filters by global angle index, so the "
                f"raw stack must be the full scan: got "
                f"{projections.shape[0]} projections for "
                f"n_proj={geom.n_proj}")
        fplan = make_filter_plan(geom, short_scan)
        # The Parker table is sharded along the projection axis exactly
        # like the projections, so rank k filters its subset with the
        # weights of the angles it holds (ones = no short-scan weights).
        pw_full = (fplan.parker if fplan.parker is not None
                   else jnp.ones((geom.n_proj, geom.n_u), jnp.float32))

    # One sharding vocabulary with the LM path (repro.dist.sharding):
    # the CT decomposition is just two more logical axes — ``vol``
    # (z-planes, the paper's OpenMP plane split) and ``proj``.
    rules = ShardingRules(vol=(volume_axis,), proj=tuple(proj_axes))
    proj_spec = logical_to_spec(("proj",), rules, mesh)
    vol_spec = logical_to_spec(("vol",), rules, mesh)

    def slab_body(local_projs, local_mats, local_volume):
        # z offset of this rank's slab: planes are contiguous per shard.
        idx = jax.lax.axis_index(volume_axis)
        slab = local_volume.shape[0]
        z0 = idx * slab
        # The slab becomes varying over the projection axes once local
        # contributions are added; mark the carry accordingly (shard_map
        # varying-manual-axes typing).
        local_volume = jax.lax.pcast(local_volume, tuple(proj_axes),
                                     to="varying")
        partial = reconstruct_shards(local_projs, local_mats, gs, plan,
                                     local_volume, z0=z0)
        # Sum the projection-sharded partial volumes.
        for ax in proj_axes:
            partial = jax.lax.psum(partial, ax)
        return partial

    if prefiltered:
        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(proj_spec, proj_spec, vol_spec),
            out_specs=vol_spec)
        def run(local_projs, local_mats, local_volume):
            return slab_body(local_projs, local_mats, local_volume)
    else:
        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(proj_spec, proj_spec, proj_spec, vol_spec),
            out_specs=vol_spec)
        def run(local_projs, local_mats, local_pw, local_volume):
            local_projs = apply_filter(local_projs, fplan, local_pw)
            return slab_body(local_projs, local_mats, local_volume)

    with sharding_context(mesh, rules):
        # shard_constraint is the placement mechanism here — the same
        # annotation idiom (and specs) the LM layers use, not a parallel
        # device_put path.
        volume = shard_constraint(
            jnp.zeros((gs.L, gs.L, gs.L), dtype=jnp.float32),
            ("vol", None, None))
        projections = shard_constraint(jnp.asarray(projections),
                                       ("proj", None, None))
        matrices = shard_constraint(jnp.asarray(matrices, jnp.float32),
                                    ("proj", None, None))
        if prefiltered:
            return run(projections, matrices, volume)
        pw_full = shard_constraint(pw_full, ("proj", None))
        return run(projections, matrices, pw_full, volume)
