"""Clipping masks and strip planning (host-side geometry precompute).

The paper improves *fastrabbit*'s "clipping mask": per ``(z, y)`` voxel line,
precompute the exact ``x`` index range whose projection lands on the detector
and skip the rest (about 10% of all voxels for a 512^3 volume).  This module
reproduces that — and extends it into the TPU analogue of the paper's
software-prefetch story: a **strip plan** that, per ``(projection, z, y,
x-chunk)``, records the origin of the minimal detector rectangle ("strip")
containing every bilinear tap of the chunk.  The plan feeds

* the ``strip`` jnp strategy (structured ``dynamic_slice`` block loads — the
  analogue of fastrabbit's pairwise loads), and
* the Pallas kernel's scalar-prefetch ``index_map`` (the strip is DMA'd
  HBM->VMEM one grid step ahead — the latency hiding KNC lacked).

Monotone-beam property
----------------------
For a fixed ``(z, y)`` line, ``Z(x)`` (the homogeneous coordinate) is affine
in ``x`` and both detector coordinates are projective in ``x``:

* ``iy(x) = f * wz / Z(x) + cv`` is monotone (``1/Z`` is monotone where
  ``Z > 0``), and
* ``d(ix)/dx`` has the sign of ``U'Z - U Z'`` which is *constant* along the
  line, so ``ix(x)`` is monotone too.

Hence per-chunk strip bounds are exact from the chunk's two endpoint voxels.
This property is verified against brute force in
``tests/test_clipping.py`` (hypothesis sweep).

All computations here are float64 numpy on the host — the same division of
labour as the RabbitCT framework, which precomputes matrices host-side.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .geometry import Geometry

__all__ = [
    "LinePlan",
    "StripPlan",
    "pad_projection",
    "line_clip_exact",
    "line_clip_conservative",
    "plan_strips",
    "shared_window_requirement",
]

# Margin (pixels) added around the analytic tap bounds: one for the floor()
# tap pair, one for float32-vs-float64 index disagreement near integers.
_MARGIN = 2


@dataclasses.dataclass(frozen=True)
class LinePlan:
    """Exact per-line clip ranges: process ``x`` in ``[x0, x1)``."""

    x0: np.ndarray  # (L, L) int32, indexed [z, y]
    x1: np.ndarray  # (L, L) int32

    @property
    def voxels(self) -> int:
        return int(np.maximum(self.x1 - self.x0, 0).sum())


@dataclasses.dataclass(frozen=True)
class StripPlan:
    """Per-chunk strip origins in *padded* image coordinates.

    ``r0``/``c0`` have shape ``(L, L, n_chunks)`` indexed ``[z, y, chunk]``.
    ``band``/``width`` are the static strip dims every chunk fits in.
    ``active`` marks chunks with at least one contributing voxel.
    """

    r0: np.ndarray
    c0: np.ndarray
    active: np.ndarray
    chunk: int
    band: int
    width: int
    required_band: int
    required_width: int


def pad_projection(image: np.ndarray) -> np.ndarray:
    """Zero-pad by one pixel on every side (paper section 5.1.1).

    The paper found that copying projections into a zero-padded buffer and
    dropping the per-tap bounds checks beats masked gathers.  With a 1-pixel
    border, *every* bilinear tap of a voxel whose footprint touches the
    detector maps to a well-defined padded pixel, and all out-of-detector
    taps map either to the zero border or outside any planned strip (where
    the one-hot selection contributes zero by construction).
    """
    n_v, n_u = image.shape[-2:]
    out = np.zeros(image.shape[:-2] + (n_v + 2, n_u + 2), dtype=image.dtype)
    out[..., 1:-1, 1:-1] = image
    return out


# ----------------------------------------------------------------------
# Exact per-line clipping (paper's improved clipping mask)
# ----------------------------------------------------------------------

def _line_coeffs(geom: Geometry, A: np.ndarray):
    """Affine coefficients of (u', v', w) along x for all (z, y) lines.

    Returns arrays shaped (L, L) for the x=0 intercepts and scalars for the
    common slopes: ``u'(x) = pu + qu * x`` etc.
    """
    L = geom.L
    wcoord = geom.O + np.arange(L, dtype=np.float64) * geom.MM
    wy = wcoord[None, :, None]   # y varies on axis 1
    wz = wcoord[:, None, None]   # z varies on axis 0
    w0 = geom.O                  # world x at voxel x=0
    pu = A[0, 0] * w0 + A[0, 1] * wy + A[0, 2] * wz + A[0, 3]
    pv = A[1, 0] * w0 + A[1, 1] * wy + A[1, 2] * wz + A[1, 3]
    pw = A[2, 0] * w0 + A[2, 1] * wy + A[2, 2] * wz + A[2, 3]
    qu = A[0, 0] * geom.MM
    qv = A[1, 0] * geom.MM
    qw = A[2, 0] * geom.MM
    return (pu[..., 0], pv[..., 0], pw[..., 0]), (qu, qv, qw)


def _halfline(acc_lo, acc_hi, a, b):
    """Intersect {x : a + b*x > 0} into interval [acc_lo, acc_hi]."""
    with np.errstate(divide="ignore", invalid="ignore"):
        root = -a / b
    pos_b = b > 0
    neg_b = b < 0
    zero_b = b == 0
    lo = np.where(pos_b, np.maximum(acc_lo, root), acc_lo)
    hi = np.where(neg_b, np.minimum(acc_hi, root), acc_hi)
    # b == 0: condition is just a > 0 (empty interval if it fails).
    dead = zero_b & (a <= 0)
    lo = np.where(dead, np.inf, lo)
    hi = np.where(dead, -np.inf, hi)
    return lo, hi


def line_clip_exact(geom: Geometry, A: np.ndarray,
                    eps_w: float = 1e-6) -> LinePlan:
    """Exact ``[x0, x1)`` per line such that outside it no tap contributes.

    A voxel contributes iff ``-1 < ix < n_u`` and ``-1 < iy < n_v`` and
    ``w > 0``.  Each bound is a linear inequality in ``x`` (after
    multiplying through by ``w > 0``), so the valid set is an interval —
    the "improved clipping mask" of paper section 5.
    """
    (pu, pv, pw), (qu, qv, qw) = _line_coeffs(geom, A)
    L = geom.L
    lo = np.full(pu.shape, -np.inf)
    hi = np.full(pu.shape, np.inf)
    # w > eps
    lo, hi = _halfline(lo, hi, pw - eps_w, np.full_like(pw, qw))
    # ix > -1   <=>  u' + w > 0
    lo, hi = _halfline(lo, hi, pu + pw, np.full_like(pw, qu + qw))
    # ix < n_u  <=>  n_u * w - u' > 0
    lo, hi = _halfline(lo, hi, geom.n_u * pw - pu,
                       np.full_like(pw, geom.n_u * qw - qu))
    # iy > -1
    lo, hi = _halfline(lo, hi, pv + pw, np.full_like(pw, qv + qw))
    # iy < n_v
    lo, hi = _halfline(lo, hi, geom.n_v * pw - pv,
                       np.full_like(pw, geom.n_v * qw - qv))
    x0 = np.clip(np.ceil(lo), 0, L).astype(np.int32)
    x1 = np.clip(np.floor(hi) + 1, 0, L).astype(np.int32)
    x1 = np.maximum(x1, x0)
    return LinePlan(x0=x0, x1=x1)


def line_clip_conservative(geom: Geometry, A: np.ndarray) -> LinePlan:
    """The pre-fix mask: per z-plane all-or-nothing corner test.

    Mirrors the "original algorithm with minor flaws" the paper improved
    on: project the four corners of each z-plane; if any corner's footprint
    may touch the detector, process *every* voxel of the plane.  Used by
    ``benchmarks/table3`` to reproduce the ~10% voxel-reduction claim.
    """
    from .geometry import project_voxels, voxel_world_coords

    L = geom.L
    corners = voxel_world_coords(geom, np.array([0, L - 1], dtype=np.float64))
    x0 = np.zeros((L, L), dtype=np.int32)
    x1 = np.zeros((L, L), dtype=np.int32)
    for zi in range(L):
        wz = voxel_world_coords(geom, zi)
        cx, cy = np.meshgrid(corners, corners)
        ix, iy, w = project_voxels(A, cx.ravel(), cy.ravel(),
                                   np.full(4, wz))
        if (w <= 0).any():
            # Projective hull argument breaks behind the source; take
            # the whole plane.
            x1[zi, :] = L
            continue
        # The plane's projection lies in the convex hull of its corner
        # projections (w > 0), so a bounding-box overlap test is truly
        # conservative.  (An "any corner inside" test is NOT — detector
        # cones can cross a plane whose corners all miss; cf. the
        # paper's remark that the original mask "had minor flaws".)
        hit = ((ix.max() > -1) & (ix.min() < geom.n_u)
               & (iy.max() > -1) & (iy.min() < geom.n_v))
        x1[zi, :] = L if hit else 0
    return LinePlan(x0=x0, x1=x1)


# ----------------------------------------------------------------------
# Strip planning (feeds the `strip` strategy and the Pallas kernel)
# ----------------------------------------------------------------------

def plan_strips(geom: Geometry, A: np.ndarray, chunk: int,
                band: int | None = None, width: int | None = None,
                clip: LinePlan | None = None) -> StripPlan:
    """Compute per-chunk strip origins in padded-image coordinates.

    Exactness relies on the monotone-beam property (module docstring): the
    tap bounding box of an x-chunk is spanned by its endpoint voxels.  The
    returned ``required_band``/``required_width`` are the tight maxima over
    all *active* chunks; callers pass static ``band``/``width`` at least
    that large (asserted by the strategies).
    """
    if clip is None:
        clip = line_clip_exact(geom, A)
    L = geom.L
    assert L % chunk == 0, (L, chunk)
    n_chunks = L // chunk
    (pu, pv, pw), (qu, qv, qw) = _line_coeffs(geom, A)

    xs = np.arange(n_chunks) * chunk

    # Effective endpoints: the chunk extent intersected with the exact clip
    # range.  This guarantees ``w > 0`` at both endpoints (the clip range
    # enforces it), so the projective coordinates there are meaningful, and
    # by monotonicity every contributing tap lies between them.
    x0 = clip.x0[..., None].astype(np.float64)       # (L, L, 1)
    x1 = clip.x1[..., None].astype(np.float64)
    xa = np.maximum(xs[None, None, :].astype(np.float64), x0)
    xb = np.minimum((xs + chunk - 1)[None, None, :].astype(np.float64),
                    x1 - 1.0)
    xb = np.maximum(xb, xa)                          # degenerate -> point

    def coords(xq):  # xq: (L, L, n_chunks)
        u = pu[..., None] + qu * xq
        v = pv[..., None] + qv * xq
        w = pw[..., None] + qw * xq
        w = np.where(np.abs(w) < 1e-12, 1e-12, w)
        return u / w, v / w, w

    ix_a, iy_a, w_a = coords(xa)
    ix_b, iy_b, w_b = coords(xb)

    # Clamp projected coords into the padded-image footprint before taking
    # bounds: contributions outside it are zero anyway.
    def pclip_c(ix):
        return np.clip(ix, -1.0, float(geom.n_u))

    def pclip_r(iy):
        return np.clip(iy, -1.0, float(geom.n_v))

    c_lo = np.floor(np.minimum(pclip_c(ix_a), pclip_c(ix_b)))
    c_hi = np.floor(np.maximum(pclip_c(ix_a), pclip_c(ix_b))) + 1
    r_lo = np.floor(np.minimum(pclip_r(iy_a), pclip_r(iy_b)))
    r_hi = np.floor(np.maximum(pclip_r(iy_a), pclip_r(iy_b))) + 1

    # Active chunks: nonempty overlap between the [x0, x1) clip range and
    # the chunk extent.
    active = (np.minimum(x1, (xs + chunk)[None, None, :].astype(np.float64))
              > np.maximum(x0, xs[None, None, :].astype(np.float64)))

    req_band = int(np.max(np.where(active, r_hi - r_lo, 0)) + _MARGIN)
    req_width = int(np.max(np.where(active, c_hi - c_lo, 0)) + _MARGIN)
    band = int(band) if band is not None else _round8(req_band)
    width = int(width) if width is not None else _round128(req_width)

    # Origins in padded coordinates (padded pixel p maps image index p-1),
    # clamped so the strip stays inside the padded image.
    r0 = np.clip(r_lo + 1 - _MARGIN // 2, 0, geom.n_v + 2 - band)
    c0 = np.clip(c_lo + 1 - _MARGIN // 2, 0, geom.n_u + 2 - width)
    return StripPlan(
        r0=r0.astype(np.int32), c0=c0.astype(np.int32),
        active=active, chunk=chunk, band=band, width=width,
        required_band=req_band, required_width=req_width)


def _round8(v: int) -> int:
    return max(8, (v + 7) // 8 * 8)


def _round128(v: int) -> int:
    return max(128, (v + 127) // 128 * 128)


def shared_window_requirement(geom: Geometry, matrices, *, ty: int,
                              chunk: int, pbatch: int) -> tuple[int, int]:
    """Superset-window dims covering a whole projection group per tile.

    The shared-window batch kernel DMAs ONE ``(pbatch, band, width)``
    window slab per ``(z, ty-lines, x-chunk)`` volume tile, anchored at
    the elementwise minimum of the group members' strip origins.  For
    that window to cover every member's taps, its dims must span the
    group's origin scatter — across the ``ty`` merged lines (as in the
    per-projection ``validate_strip_config`` check) *and* across the
    ``pbatch`` projections of the group.

    Groups mirror the batch drivers' chunking (``_stream_batches``):
    full ``pbatch`` groups from index 0 plus one smaller remainder
    group.  Returns the tight ``(need_band, need_width)`` maxima over
    all groups and tiles; callers must use a window at least that large
    or taps silently drop — same loud-or-correct contract as
    :func:`plan_strips` consumers.
    """
    mats = np.asarray(matrices, np.float64).reshape(-1, 3, 4)
    L = geom.L
    assert L % ty == 0 and L % chunk == 0, (L, ty, chunk)
    plans = [plan_strips(geom, A, chunk=chunk) for A in mats]
    need_band = need_width = 0
    for g0 in range(0, len(plans), pbatch):
        grp = plans[g0:g0 + pbatch]
        r0 = np.stack([p.r0.astype(np.int64) for p in grp])
        c0 = np.stack([p.c0.astype(np.int64) for p in grp])
        rb = max(p.required_band for p in grp)
        rw = max(p.required_width for p in grp)
        # Merge over group members (axis 0) and the ty lines a volume
        # tile spans (axis 3 after the reshape) — the kernel serves all
        # of them from one window.
        gr = r0.reshape(len(grp), L, L // ty, ty, -1)
        gc = c0.reshape(len(grp), L, L // ty, ty, -1)
        span_r = gr.max(axis=(0, 3)) - gr.min(axis=(0, 3)) + rb
        span_c = gc.max(axis=(0, 3)) - gc.min(axis=(0, 3)) + rw
        need_band = max(need_band, int(span_r.max()))
        need_width = max(need_width, int(span_c.max()))
    return need_band, need_width
