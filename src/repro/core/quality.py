"""RabbitCT-style reconstruction quality metrics.

RabbitCT scores entries on speed *and* accuracy (mean squared error and
PSNR against a reference volume, evaluated over the inscribed sphere of the
volume so the corners — which some projections never see — don't bias the
score).  We keep that convention and evaluate against the *analytic*
voxelised phantom.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["roi_mask", "mse", "psnr", "quality_report"]


def roi_mask(L: int) -> np.ndarray:
    """Boolean mask of the inscribed sphere (RabbitCT's scoring region)."""
    c = (L - 1) / 2.0
    g = np.arange(L, dtype=np.float64) - c
    zz, yy, xx = np.meshgrid(g, g, g, indexing="ij")
    return (xx * xx + yy * yy + zz * zz) <= c * c


def mse(volume, reference, mask=None):
    volume = jnp.asarray(volume, jnp.float32)
    reference = jnp.asarray(reference, jnp.float32)
    err = (volume - reference) ** 2
    if mask is not None:
        mask = jnp.asarray(mask)
        return jnp.sum(err * mask) / jnp.sum(mask)
    return jnp.mean(err)


def psnr(volume, reference, mask=None, data_range: float | None = None):
    """Peak signal-to-noise ratio in dB (RabbitCT's headline metric)."""
    if data_range is None:
        data_range = float(jnp.max(jnp.asarray(reference))
                           - jnp.min(jnp.asarray(reference)))
        data_range = data_range or 1.0
    m = mse(volume, reference, mask)
    return 10.0 * jnp.log10((data_range ** 2) / jnp.maximum(m, 1e-20))


def quality_report(volume, reference) -> dict:
    L = int(np.asarray(volume).shape[0])
    mask = roi_mask(L)
    return {
        "mse_roi": float(mse(volume, reference, mask)),
        "psnr_roi_db": float(psnr(volume, reference, mask)),
        "mse_full": float(mse(volume, reference)),
        "psnr_full_db": float(psnr(volume, reference)),
    }
