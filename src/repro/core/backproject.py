"""Voxel-driven cone-beam back projection — the paper's kernel, in JAX.

Listing 1 of the paper splits the line-update kernel into three parts; we
keep that structure so the HLO op census (``benchmarks/table2``) can be
reported per part:

* **Part 1** (:func:`plane_coords`): VCS->WCS->ICS transform +
  de-homogenisation.  Streaming arithmetic; trivially vectorizable on any
  SIMD machine — and on the TPU VPU.
* **Part 2** (``sample_*``): fetch the four bilinear taps and blend them.
  The scattered-access part; each ``sample_*`` function is one point in the
  x86-ISA -> TPU design-space mapping (see DESIGN.md §2):

  ========== ==========================================================
  strategy    TPU mechanism (x86 analogue)
  ========== ==========================================================
  ``scalar``  per-tap bounds-checked loads (scalar baseline, Listing 1)
  ``gather``  XLA gather HLO on a zero-padded image (AVX2/IMCI
              ``vgatherdps``)
  ``onehot``  full one-hot matmuls on the MXU (GPU texture-unit
              emulation; the systolic array performs the interpolation)
  ``strip``   per-chunk strip block load + banded one-hot
              (SSE/AVX pairwise loads + in-register shuffles)
  ``strip2``  two-level: strip -> per-8-voxel micro-window + VPU selects
              (beyond-paper refinement; the Pallas kernel's scheme)
  ========== ==========================================================

* **Part 3** (:func:`accumulate`): inverse-square-law weighting + voxel
  update.  Streaming; includes the paper's reciprocal trick (one
  reciprocal replaces three divides).

All strategies implement *identical* semantics — floor-based bilinear
interpolation with zero outside the detector — and are cross-validated in
``tests/test_backproject.py``.  (The reference C code's ``(int)`` cast
truncates toward zero, which *extrapolates* for ``ix in (-1, 0)``; we use
mathematically correct ``floor`` semantics everywhere.  The difference is
confined to a sub-pixel border band and is invisible in the quality
metric.)
"""

from __future__ import annotations

import functools
import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import RowQuant, quantize_rows

from .geometry import Geometry

__all__ = [
    "STRATEGIES",
    "DEFAULT_PBATCH",
    "GeomStatic",
    "plane_coords",
    "sample_scalar",
    "sample_gather",
    "sample_onehot",
    "sample_strip",
    "sample_strip2",
    "strip_wire_dtype",
    "contribution",
    "accumulate",
    "backproject_plane",
    "backproject_plane_batch",
    "backproject_one",
    "backproject_batch",
    "fold_projections",
    "validate_strip_opts",
    "reconstruct",
]

STRATEGIES = ("scalar", "gather", "onehot", "strip", "strip2")

# Wire dtypes the strip strategies (and the Pallas kernels) may carry
# strip data in.  ``None`` means "leave the image dtype alone" — the
# float32 path must stay bitwise-identical to the pre-option code, so it
# never inserts so much as a no-op ``astype``.  bf16 halves strip HBM/
# VMEM bytes; the one-hot interpolation always upcasts the window back
# to f32 and accumulates in f32, so the only quality loss is the bf16
# rounding of the strip values themselves (~8 mantissa bits).  int8
# quarters them: the padded image is encoded ONCE at pad time into
# per-row affine codes + f32 scale/offset (``repro.quant``, error
# feedback along each row), windows move at 1 byte/pixel, and the
# samplers dequantise *after* the gather next to the f32 accumulator
# (DESIGN.md §12).
_STRIP_WIRE_DTYPES = {"float32": None, "bfloat16": jnp.bfloat16,
                      "int8": jnp.int8}


def strip_wire_dtype(strip_dtype: str):
    """Map a ``strip_dtype`` option to a jnp dtype (``None`` = f32
    passthrough).  Raises ``ValueError`` on unknown names — a typo'd
    dtype must never silently run the f32 path."""
    try:
        return _STRIP_WIRE_DTYPES[str(strip_dtype)]
    except KeyError:
        raise ValueError(
            f"unknown strip_dtype {strip_dtype!r}; want one of "
            f"{tuple(_STRIP_WIRE_DTYPES)}") from None

# Projections folded into the volume per volume pass when the caller does
# not say otherwise (untuned ``pbatch``).  Each pass streams the L^3
# volume through memory exactly once, so volume traffic scales with
# ``ceil(n_proj / pbatch)`` — see DESIGN.md §7 for the traffic model.
DEFAULT_PBATCH = 4

_EPS_W = 1e-6


class GeomStatic(NamedTuple):
    """The static scalars a kernel needs (hashable -> jit-static)."""

    L: int
    n_u: int
    n_v: int
    O: float
    MM: float

    @classmethod
    def of(cls, geom: Geometry) -> "GeomStatic":
        return cls(L=geom.L, n_u=geom.n_u, n_v=geom.n_v,
                   O=float(geom.O), MM=float(geom.MM))


# ----------------------------------------------------------------------
# Part 1 — geometry (streaming arithmetic)
# ----------------------------------------------------------------------

def plane_coords(A, gs: GeomStatic, z, *, use_reciprocal: bool = True):
    """ICS coordinates for one z-plane: ``(ix, iy, w)`` each ``(L, L)``.

    ``[y, x]`` index order.  The single reciprocal replaces the two divides
    of Listing 1 lines 14-15 (paper section 5.1: "replace the divide with a
    reciprocal instruction"); it is also reused by Part 3 for the ``1/w^2``
    weight, saving a third divide.
    """
    A = jnp.asarray(A, dtype=jnp.float32)
    coords = gs.O + jnp.arange(gs.L, dtype=jnp.float32) * gs.MM
    wx = coords[None, :]                      # (1, L)  varies along x
    wy = coords[:, None]                      # (L, 1)  varies along y
    wz = gs.O + z.astype(jnp.float32) * gs.MM if hasattr(z, "dtype") \
        else gs.O + float(z) * gs.MM
    u = wx * A[0, 0] + wy * A[0, 1] + wz * A[0, 2] + A[0, 3]
    v = wx * A[1, 0] + wy * A[1, 1] + wz * A[1, 2] + A[1, 3]
    w = wx * A[2, 0] + wy * A[2, 1] + wz * A[2, 2] + A[2, 3]
    if use_reciprocal:
        r = jnp.where(w > _EPS_W, 1.0 / w, 0.0)
        return u * r, v * r, w
    return u / w, v / w, w


def _taps(ix, iy):
    """Floor taps and interpolation weights (Listing 1 lines 17-21)."""
    fx = jnp.floor(ix)
    fy = jnp.floor(iy)
    iix = fx.astype(jnp.int32)
    iiy = fy.astype(jnp.int32)
    return iix, iiy, ix - fx, iy - fy


# ----------------------------------------------------------------------
# Part 2 — the four-tap fetch + bilinear blend (scattered access)
# ----------------------------------------------------------------------

def sample_scalar(image, ix, iy, gs: GeomStatic):
    """Listing-1 transliteration: four bounds-checked loads per voxel.

    The oracle for every other strategy.  ``image`` is the *unpadded*
    ``(n_v, n_u)`` projection; each tap is masked exactly like the four
    ``if`` statements of Listing 1 lines 24-36.
    """
    iix, iiy, sx, sy = _taps(ix, iy)

    def tap(r, c):
        ok = (r >= 0) & (r < gs.n_v) & (c >= 0) & (c < gs.n_u)
        rc = jnp.clip(r, 0, gs.n_v - 1)
        cc = jnp.clip(c, 0, gs.n_u - 1)
        return jnp.where(ok, image[rc, cc], 0.0)

    valbl = tap(iiy, iix)
    valbr = tap(iiy, iix + 1)
    valtl = tap(iiy + 1, iix)
    valtr = tap(iiy + 1, iix + 1)
    valb = (1.0 - sx) * valbl + sx * valbr
    valt = (1.0 - sx) * valtl + sx * valtr
    return (1.0 - sy) * valb + sy * valt


def sample_gather(padded, ix, iy, gs: GeomStatic):
    """Hardware-gather analogue: four XLA gathers on the padded image.

    ``padded`` is the 1-pixel zero-padded ``(n_v + 2, n_u + 2)`` buffer
    (paper section 5.1.1: zero padding beats mask registers).  Indices are
    clamped into the padded buffer; every clamped-out tap lands on a zero
    border cell, so no per-tap conditional survives — exactly the paper's
    "gather everything unconditionally" scheme.
    """
    iix, iiy, sx, sy = _taps(ix, iy)
    r = jnp.clip(iiy + 1, 0, gs.n_v + 1)
    r2 = jnp.clip(iiy + 2, 0, gs.n_v + 1)
    c = jnp.clip(iix + 1, 0, gs.n_u + 1)
    c2 = jnp.clip(iix + 2, 0, gs.n_u + 1)
    valbl = padded[r, c]
    valbr = padded[r, c2]
    valtl = padded[r2, c]
    valtr = padded[r2, c2]
    valb = (1.0 - sx) * valbl + sx * valbr
    valt = (1.0 - sx) * valtl + sx * valtr
    return (1.0 - sy) * valb + sy * valt


def sample_onehot(padded, ix, iy, gs: GeomStatic, *, vox_block: int = 512):
    """Texture-unit emulation: bilinear sampling as two one-hot matmuls.

    ``val[p] = rowsel[p, :] @ padded @ colsel[p, :]`` where ``rowsel``
    carries the vertical interpolation weights on taps ``iiy``/``iiy+1``
    and ``colsel`` the horizontal ones.  The MXU performs the
    interpolation, like a GPU texture unit — at the cost of ``2*R + 4*W``
    flops per voxel.  Out-of-range taps produce all-zero one-hot rows, so
    the zero-outside semantics are *exact* with no clamping at all.
    """
    R, W = gs.n_v + 2, gs.n_u + 2
    shape = ix.shape
    n = int(np.prod(shape))
    vb = min(vox_block, n)
    pad_to = (-n) % vb

    iix, iiy, sx, sy = _taps(ix, iy)
    flat = [jnp.pad(a.reshape(-1), (0, pad_to)).reshape(-1, vb)
            for a in (iix, iiy, sx, sy)]
    iixf, iiyf, sxf, syf = flat

    riota = jax.lax.broadcasted_iota(jnp.int32, (vb, R), 1)
    ciota = jax.lax.broadcasted_iota(jnp.int32, (vb, W), 1)

    def block(args):
        iixb, iiyb, sxb, syb = args
        rr = iiyb[:, None] + 1                  # padded row of lower tap
        cc = iixb[:, None] + 1
        rowsel = ((riota == rr) * (1.0 - syb[:, None])
                  + (riota == rr + 1) * syb[:, None])
        colsel = ((ciota == cc) * (1.0 - sxb[:, None])
                  + (ciota == cc + 1) * sxb[:, None])
        rowmix = rowsel.astype(padded.dtype) @ padded     # (vb, W)
        return jnp.sum(rowmix * colsel, axis=-1)

    vals = jax.lax.map(block, (iixf, iiyf, sxf, syf))
    return vals.reshape(-1)[:n].reshape(shape)


def _divisor_at_most(n: int, k: int) -> int:
    """Largest divisor of ``n`` that is <= ``k`` (memory-block sizing)."""
    k = max(1, min(k, n))
    while n % k:
        k -= 1
    return k


def _strip_bounds(idx, lo_clip, hi_clip, pad_origin_max):
    """Chunk-min tap origin, clamped into the padded image.

    The lowest contributing tap of the chunk sits at padded coordinate
    ``floor(min(idx)) + 1``; using ``floor(min(idx))`` as the origin leaves
    one margin row/col below it (+1 pad and -1 margin cancel).
    """
    clipped = jnp.clip(idx, lo_clip, hi_clip)
    lo = jnp.floor(jnp.min(clipped, axis=-1)).astype(jnp.int32)
    return jnp.clip(lo, 0, pad_origin_max)


def sample_strip(padded, ix, iy, gs: GeomStatic, *, chunk: int = 128,
                 band: int = 16, width: int = 512,
                 strips_per_block: int = 64,
                 strip_dtype: str = "float32"):
    """Structured block loads: the fastrabbit "pairwise loads" analogue.

    Voxel lines are cut into x-chunks; per chunk one contiguous
    ``(band, width)`` strip is block-loaded (``dynamic_slice``) and the
    four taps are selected from it with a banded one-hot — zero XLA
    gathers of individual elements.  The strip origin is the chunk-min tap
    coordinate (exact: no monotonicity assumption needed in-graph), so all
    contributing taps are in-band by construction; out-of-band one-hot rows
    are identically zero, preserving exact zero-outside semantics.

    ``strip_dtype="bfloat16"`` carries the strips on the wire in bf16
    (halving strip bytes); the one-hot mix upcasts back to f32 and
    accumulates in f32, so only the tap *values* are rounded.  The
    default f32 path is bitwise-identical to the pre-option code.
    ``strip_dtype="int8"`` moves per-row affine codes (1 byte/pixel;
    ``padded`` may be a pre-encoded :class:`repro.quant.RowQuant` from
    the drivers' pad-time encode) and dequantises the window *after*
    the gather, at the same f32 dot the bf16 upcast uses.
    """
    wire = strip_wire_dtype(strip_dtype)
    quant = None
    if wire is jnp.int8:
        # Drivers encode once at pad time; a direct caller handing a
        # plain array pays the (per-call) encode here instead.
        quant = padded if isinstance(padded, RowQuant) \
            else quantize_rows(padded)
        padded = quant.codes
    elif isinstance(padded, RowQuant):
        raise TypeError(
            f"RowQuant-encoded image requires strip_dtype='int8'; got "
            f"{strip_dtype!r}")
    elif wire is not None:
        padded = padded.astype(wire)
    L = gs.L
    assert ix.shape == (L, L)
    chunk = _divisor_at_most(L, chunk)
    ns = L // chunk
    band = min(band, gs.n_v + 2)
    width = min(width, gs.n_u + 2)

    def reshard(a):
        return a.reshape(L * ns, chunk)

    ixs, iys = reshard(ix), reshard(iy)
    iix, iiy, sx, sy = _taps(ixs, iys)

    r0 = _strip_bounds(iys, -1.0, float(gs.n_v), gs.n_v + 2 - band)
    c0 = _strip_bounds(ixs, -1.0, float(gs.n_u), gs.n_u + 2 - width)

    rel_r = iiy + 1 - r0[:, None]                # padded-relative tap rows
    rel_c = iix + 1 - c0[:, None]

    biota = jax.lax.broadcasted_iota(jnp.int32, (chunk, band), 1)
    wiota = jax.lax.broadcasted_iota(jnp.int32, (chunk, width), 1)

    nstrips = L * ns
    spb = _divisor_at_most(nstrips, strips_per_block)

    def block(args):
        r0b, c0b, rrel, crel, sxb, syb = args

        def one(r0i, c0i, rreli, creli, sxi, syi):
            strip = jax.lax.dynamic_slice(padded, (r0i, c0i), (band, width))
            rowsel = ((biota == rreli[:, None]) * (1.0 - syi[:, None])
                      + (biota == rreli[:, None] + 1) * syi[:, None])
            colsel = ((wiota == creli[:, None]) * (1.0 - sxi[:, None])
                      + (wiota == creli[:, None] + 1) * sxi[:, None])
            if wire is None:
                rowmix = rowsel.astype(padded.dtype) @ strip
            else:
                if quant is not None:   # int8: dequant after the gather
                    scl = jax.lax.dynamic_slice(quant.scale, (r0i,),
                                                (band,))
                    off = jax.lax.dynamic_slice(quant.offset, (r0i,),
                                                (band,))
                    strip = (strip.astype(jnp.float32) * scl[:, None]
                             + off[:, None])
                # f32 weights x (bf16 | dequantised) strip -> f32
                rowmix = jax.lax.dot_general(
                    rowsel, strip.astype(jnp.float32),
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
            return jnp.sum(rowmix * colsel, axis=-1)       # (chunk, width)

        return jax.vmap(one)(r0b, c0b, rrel, crel, sxb, syb)

    def rb(a):
        return a.reshape((nstrips // spb, spb) + a.shape[1:])

    vals = jax.lax.map(
        block, (rb(r0), rb(c0), rb(rel_r), rb(rel_c), rb(sx), rb(sy)))
    return vals.reshape(L, ns * chunk).reshape(L, L)


def sample_strip2(padded, ix, iy, gs: GeomStatic, *, group: int = 8,
                  gband: int = 8, gwidth: int = 64,
                  groups_per_block: int = 512,
                  strip_dtype: str = "float32"):
    """Two-level micro-window sampling (beyond-paper; Pallas kernel scheme).

    Refines ``strip``: per *group* of 8 voxels, a tiny
    ``(gband, gwidth)`` window is block-loaded and the taps selected with
    VPU-width one-hot compares.  Per-voxel cost drops from
    ``2*band*width`` flops to ``~2*gband*gwidth`` — the napkin math behind
    hillclimb iteration CT-1 in EXPERIMENTS.md.  Semantics identical to
    every other strategy *provided* the window covers the group's tap
    footprint — taps past the window edge select all-zero one-hot rows
    and vanish silently, which is why :func:`reconstruct` runs the
    planner-backed :func:`validate_strip_opts` check.  (``gband`` used to
    default to 4, which silently dropped taps for standard RabbitCT-scaled
    geometries at L>=48; 8 covers every geometry in the repo's sweeps.)

    ``strip_dtype="bfloat16"``: bf16 windows on the wire, f32 upcast at
    the one-hot mix, f32 accumulate; ``strip_dtype="int8"``: per-row
    affine codes on the wire, dequantised after the gather (see
    :func:`sample_strip`).
    """
    wire = strip_wire_dtype(strip_dtype)
    quant = None
    if wire is jnp.int8:
        quant = padded if isinstance(padded, RowQuant) \
            else quantize_rows(padded)
        padded = quant.codes
    elif isinstance(padded, RowQuant):
        raise TypeError(
            f"RowQuant-encoded image requires strip_dtype='int8'; got "
            f"{strip_dtype!r}")
    elif wire is not None:
        padded = padded.astype(wire)
    L = gs.L
    group = _divisor_at_most(L, group)
    ng = L // group
    gband = min(gband, gs.n_v + 2)
    gwidth = min(gwidth, gs.n_u + 2)
    ixg = ix.reshape(L * ng, group)
    iyg = iy.reshape(L * ng, group)
    iix, iiy, sx, sy = _taps(ixg, iyg)

    r0 = _strip_bounds(iyg, -1.0, float(gs.n_v), gs.n_v + 2 - gband)
    c0 = _strip_bounds(ixg, -1.0, float(gs.n_u), gs.n_u + 2 - gwidth)
    rel_r = iiy + 1 - r0[:, None]
    rel_c = iix + 1 - c0[:, None]

    biota = jax.lax.broadcasted_iota(jnp.int32, (group, gband), 1)
    wiota = jax.lax.broadcasted_iota(jnp.int32, (group, gwidth), 1)

    ngroups = L * ng
    gpb = _divisor_at_most(ngroups, groups_per_block)

    def block(args):
        r0b, c0b, rrel, crel, sxb, syb = args

        def one(r0i, c0i, rreli, creli, sxi, syi):
            win = jax.lax.dynamic_slice(padded, (r0i, c0i), (gband, gwidth))
            rowsel = ((biota == rreli[:, None]) * (1.0 - syi[:, None])
                      + (biota == rreli[:, None] + 1) * syi[:, None])
            colsel = ((wiota == creli[:, None]) * (1.0 - sxi[:, None])
                      + (wiota == creli[:, None] + 1) * sxi[:, None])
            if wire is None:
                rowmix = rowsel.astype(padded.dtype) @ win
            else:
                if quant is not None:   # int8: dequant after the gather
                    scl = jax.lax.dynamic_slice(quant.scale, (r0i,),
                                                (gband,))
                    off = jax.lax.dynamic_slice(quant.offset, (r0i,),
                                                (gband,))
                    win = (win.astype(jnp.float32) * scl[:, None]
                           + off[:, None])
                # f32 weights x (bf16 | dequantised) window -> f32
                rowmix = jax.lax.dot_general(
                    rowsel, win.astype(jnp.float32),
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
            return jnp.sum(rowmix * colsel, axis=-1)       # (group, gwidth)

        return jax.vmap(one)(r0b, c0b, rrel, crel, sxb, syb)

    def rb(a):
        return a.reshape((ngroups // gpb, gpb) + a.shape[1:])

    vals = jax.lax.map(
        block, (rb(r0), rb(c0), rb(rel_r), rb(rel_c), rb(sx), rb(sy)))
    return vals.reshape(L, L)


# ----------------------------------------------------------------------
# Part 3 — weighting + voxel update (streaming)
# ----------------------------------------------------------------------

def contribution(val, w, clip_mask=None):
    """``val / w**2``: one projection's additive contribution to a plane.

    ``w <= 0`` voxels (behind the source; impossible for sane geometries
    but reachable in property-test sweeps) contribute zero.  Split out of
    :func:`accumulate` so the batched plane update can sum several
    projections' contributions before touching the plane once.
    """
    r = jnp.where(w > _EPS_W, 1.0 / w, 0.0)
    contrib = val * (r * r)
    if clip_mask is not None:
        contrib = contrib * clip_mask
    return contrib


def accumulate(plane, val, w, clip_mask=None):
    """``VOL += val / w**2`` with the reciprocal already amortised."""
    return plane + contribution(val, w, clip_mask).astype(plane.dtype)


# ----------------------------------------------------------------------
# Drivers
# ----------------------------------------------------------------------

def _pad_image(image):
    return jnp.pad(image, ((1, 1), (1, 1)))


def _wire_padded(padded, opts):
    """Encode the padded image(s) once at pad time for the int8 wire.

    The drivers call this right after :func:`_pad_image`, *outside* the
    z-plane ``fori_loop`` — the encode (a ``lax.scan`` along each row's
    columns carrying the error-feedback residual) is loop-invariant but
    XLA will not hoist it out of a ``while``, so it must happen here,
    not inside the samplers.  Every other wire dtype passes through
    untouched (the f32 path stays bitwise-identical; bf16 casts inside
    the samplers as before).
    """
    if opts.get("strip_dtype") != "int8":
        return padded
    if padded.ndim == 3:                # stacked projections
        return jax.vmap(quantize_rows)(padded)
    return quantize_rows(padded)


def _sample(strategy, image, padded, ix, iy, gs, opts):
    if strategy == "scalar":
        return sample_scalar(image, ix, iy, gs)
    if strategy == "gather":
        return sample_gather(padded, ix, iy, gs)
    if strategy == "onehot":
        return sample_onehot(padded, ix, iy, gs, **opts)
    if strategy == "strip":
        return sample_strip(padded, ix, iy, gs, **opts)
    if strategy == "strip2":
        return sample_strip2(padded, ix, iy, gs, **opts)
    raise ValueError(f"unknown strategy {strategy!r}; want {STRATEGIES}")


def backproject_plane(plane, image, padded, A, gs: GeomStatic, z,
                      strategy: str = "strip2", clip_mask=None, **opts):
    """Back-project one projection into one z-plane of the volume."""
    ix, iy, w = plane_coords(A, gs, z)
    val = _sample(strategy, image, padded, ix, iy, gs, opts)
    return accumulate(plane, val, w, clip_mask)


def backproject_plane_batch(plane, images, padded, mats, gs: GeomStatic, z,
                            strategy: str = "strip2", clip_mask=None,
                            **opts):
    """Back-project a *batch* of projections into one z-plane.

    The inverted loop nest (DESIGN.md §7): the plane is read once,
    receives the summed contribution of every projection in the batch
    (Part 1 vmapped over the batch), and is written once — volume
    traffic per reconstruction drops from ``2·n_proj·L³`` to
    ``2·ceil(n_proj/pbatch)·L³`` elements.  Summation order per voxel is
    projection-major within the batch, so results match the sequential
    path to fp32 rounding, not bit-for-bit.
    """

    def one(image, pimg, A):
        ix, iy, w = plane_coords(A, gs, z)
        val = _sample(strategy, image, pimg, ix, iy, gs, opts)
        return contribution(val, w, clip_mask)

    contribs = jax.vmap(one)(images, padded, mats)
    return plane + jnp.sum(contribs, axis=0).astype(plane.dtype)


def _explicit_plan(strategy: str, opts: dict, pbatch: int | None = None):
    """Strictly validated plan for an explicitly named strategy.

    Lazy import: ``repro.dispatch`` depends on this module, so the plan
    type is only pulled in at call time (same pattern as the old
    ``repro.tune.cache`` imports).
    """
    from repro.dispatch.plan import ExecutionPlan

    return ExecutionPlan.explicit(strategy, opts, pbatch)


@functools.partial(jax.jit, static_argnames=("gs", "plan"))
def _backproject_one_jit(volume, image, A, gs, plan):
    opts = plan.jnp_opts()
    padded = _wire_padded(_pad_image(image), opts)

    def body(z, vol):
        plane = jax.lax.dynamic_index_in_dim(vol, z, axis=0, keepdims=False)
        plane = backproject_plane(plane, image, padded, A, gs, z,
                                  plan.strategy, **opts)
        return jax.lax.dynamic_update_index_in_dim(vol, plane, z, axis=0)

    return jax.lax.fori_loop(0, gs.L, body, volume)


def backproject_one(volume, image, A, geom: Geometry | GeomStatic,
                    strategy: str = "strip2", **opts):
    """Add one projection's contribution to ``volume`` (``(L, L, L)``)."""
    gs = geom if isinstance(geom, GeomStatic) else GeomStatic.of(geom)
    plan = _explicit_plan(strategy, opts)
    return _backproject_one_jit(volume, jnp.asarray(image),
                                jnp.asarray(A, jnp.float32), gs, plan)


def _backproject_batch_body(volume, images, mats, gs: GeomStatic, plan,
                            z0):
    """Volume-resident update for one projection batch (plane-major).

    ``volume`` may be a z-slab: the plane loop runs over
    ``volume.shape[0]`` and ``z0`` is the slab's first global z index
    (traced; the sharded pipeline passes its rank offset).  ``plan`` is
    the resolved :class:`repro.dispatch.ExecutionPlan`.  Callers jit.
    """
    strategy, opts = plan.strategy, plan.jnp_opts()
    padded = _wire_padded(jax.vmap(_pad_image)(images), opts)

    def body(zi, vol):
        plane = jax.lax.dynamic_index_in_dim(vol, zi, axis=0, keepdims=False)
        plane = backproject_plane_batch(plane, images, padded, mats, gs,
                                        z0 + zi, strategy, **opts)
        return jax.lax.dynamic_update_index_in_dim(vol, plane, zi, axis=0)

    return jax.lax.fori_loop(0, volume.shape[0], body, volume)


def _stream_batches(projections, matrices, volume, pbatch: int, call):
    """Fold the projection stack into ``volume``, ``pbatch`` at a time.

    The one batch-chunking driver every batched backend shares (jnp here,
    the Pallas wrapper in ``kernels/backproject_ops.py``): full batches
    run under a ``fori_loop`` (one static batch shape), and a ``pbatch ∤
    n_proj`` remainder runs as one final smaller batch — shapes are
    static because ``n_proj`` is known at trace time.  ``call(vol, imgs,
    mats)`` performs one volume pass for one batch.

    ``projections`` may be any pytree whose leaves share the leading
    projection axis (a plain stacked array, or the ``(codes, scales)``
    pair the int8 kernel wire streams) — each batch is the same
    leading-axis slice of every leaf.  A bare array is a single leaf,
    so the f32 path lowers to the identical ``dynamic_slice`` as
    before.
    """
    n_proj = jax.tree.leaves(projections)[0].shape[0]
    pbatch = max(1, min(int(pbatch), n_proj)) if n_proj else 1
    n_full = n_proj // pbatch

    def body(b, vol):
        imgs = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, b * pbatch, pbatch),
            projections)
        mats = jax.lax.dynamic_slice_in_dim(matrices, b * pbatch, pbatch)
        return call(vol, imgs, mats)

    if n_full:
        volume = jax.lax.fori_loop(0, n_full, body, volume)
    if n_proj - n_full * pbatch:
        volume = call(volume,
                      jax.tree.map(lambda a: a[n_full * pbatch:],
                                   projections),
                      matrices[n_full * pbatch:])
    return volume


def _reconstruct_batched(projections, matrices, volume, gs: GeomStatic,
                         plan, z0):
    """Stream all projections through ``volume``, ``plan.pbatch`` at a
    time.

    The inverted loop nest: batches outer, z-planes inner, projections
    innermost (vmapped) — each batch streams the volume through memory
    exactly once.
    """
    return _stream_batches(
        projections, matrices, volume, plan.pbatch,
        lambda vol, imgs, mats: _backproject_batch_body(
            vol, imgs, mats, gs, plan, z0))


def backproject_batch(volume, images, mats, geom: Geometry | GeomStatic,
                      strategy: str = "strip2",
                      pbatch: int = DEFAULT_PBATCH, **opts):
    """Add a stack of projections to ``volume``, ``pbatch`` per pass.

    The batched analogue of :func:`backproject_one` (a
    :func:`fold_projections` at ``z0=0``, sharing its jitted body):
    ``images`` is ``(n_proj, n_v, n_u)``, ``mats`` ``(n_proj, 3, 4)``.
    Unlike :func:`reconstruct` this does not validate strip windows —
    callers timing raw kernels (the tuner sweep) validate once
    themselves.
    """
    gs = geom if isinstance(geom, GeomStatic) else GeomStatic.of(geom)
    plan = _explicit_plan(strategy, opts, int(pbatch))
    return _fold_jit(jnp.asarray(volume), jnp.asarray(images),
                     jnp.asarray(mats, jnp.float32), jnp.int32(0), gs,
                     plan)


@functools.partial(jax.jit, static_argnames=("gs", "plan"))
def _fold_jit(volume, images, mats, z0, gs, plan):
    return _reconstruct_batched(images, mats, volume, gs, plan, z0)


def fold_projections(volume, images, mats, geom: Geometry | GeomStatic,
                     strategy: str = "strip2",
                     pbatch: int = DEFAULT_PBATCH, z0=0, **opts):
    """Incremental fold: add a projection *chunk* to an existing volume.

    The streaming entry point (DESIGN.md §8): unlike
    :func:`backproject_batch` the z offset ``z0`` is a traced argument,
    so one compiled fold serves every z-slab of a sharded stream, and
    ``volume`` may be a partial accumulation from earlier chunks — a
    reconstruction becomes any sequence of folds whose chunks cover the
    projection set exactly once, in any arrival order (fp32 summation
    order differs, so cross-order agreement is ~1e-5, not bitwise).
    Chunks longer than ``pbatch`` stream through
    :func:`_stream_batches` exactly like :func:`reconstruct`.

    Strip windows are validated against the host planner (memoised)
    when ``geom`` is a full :class:`Geometry`; a bare
    :class:`GeomStatic` caller must have validated the ``(geometry,
    matrices, window)`` triple itself — the planner needs the full
    acquisition description.
    """
    if isinstance(geom, Geometry):
        gs = GeomStatic.of(geom)
        validate_strip_opts(geom, mats, strategy, opts)
    else:
        gs = geom
    images = jnp.asarray(images)
    n = int(images.shape[0])
    plan = _explicit_plan(strategy, opts,
                          max(1, min(int(pbatch), n)) if n else 1)
    return _fold_jit(jnp.asarray(volume), images,
                     jnp.asarray(mats, jnp.float32),
                     jnp.asarray(z0, jnp.int32), gs, plan)


# Memo of (geometry, strategy, window, matrices) combinations already
# proven safe — validation is host-side numpy and should be paid once per
# distinct problem, not once per reconstruct() call.
_VALIDATED_STRIPS: set = set()


def validate_strip_opts(geom: Geometry, matrices, strategy: str,
                        opts: dict) -> None:
    """Planner-backed check that strip/strip2 windows cover every footprint.

    The jnp ``strip``/``strip2`` strategies select taps from a statically
    sized window with one-hot compares; a tap outside the window selects
    an all-zero row and is *silently dropped*.  The Pallas path guards
    this with ``validate_strip_config``; this is the same guard for the
    jnp paths, reusing the host planner (:func:`repro.core.clipping
    .plan_strips`, exact by the monotone-beam property).  Raises
    ``ValueError`` with the required window sizes when the static config
    is too small.  No-op for strategies without windows.
    """
    if strategy == "strip":
        chunk = _divisor_at_most(geom.L, int(opts.get("chunk", 128)))
        band = min(int(opts.get("band", 16)), geom.n_v + 2)
        width = min(int(opts.get("width", 512)), geom.n_u + 2)
        what = f"strip (chunk={chunk}, band={band}, width={width})"
    elif strategy == "strip2":
        chunk = _divisor_at_most(geom.L, int(opts.get("group", 8)))
        band = min(int(opts.get("gband", 8)), geom.n_v + 2)
        width = min(int(opts.get("gwidth", 64)), geom.n_u + 2)
        what = f"strip2 (group={chunk}, gband={band}, gwidth={width})"
    else:
        return
    if isinstance(matrices, jax.core.Tracer):
        return                      # in-trace call: host check impossible
    mats = np.asarray(matrices, np.float64).reshape(-1, 3, 4)
    key = (GeomStatic.of(geom), strategy, chunk, band, width,
           hashlib.sha1(mats.tobytes()).hexdigest())
    if key in _VALIDATED_STRIPS:
        return
    from .clipping import plan_strips

    need_band = need_width = 0
    for A in mats:
        plan = plan_strips(geom, A, chunk=chunk)
        need_band = max(need_band, plan.required_band)
        need_width = max(need_width, plan.required_width)
    # A full-detector window can never lose a tap: its origin clamps to 0
    # and it spans the whole padded image, so the planner's margin must
    # not push the requirement past the satisfiable maximum.
    need_band = min(need_band, geom.n_v + 2)
    need_width = min(need_width, geom.n_u + 2)
    if band < need_band or width < need_width:
        raise ValueError(
            f"{what} does not cover the chunk tap footprint for this "
            f"geometry; need at least (band={need_band}, "
            f"width={need_width}) — undersized windows drop taps "
            f"silently")
    if len(_VALIDATED_STRIPS) >= 4096:   # bound a long-lived process
        _VALIDATED_STRIPS.clear()
    _VALIDATED_STRIPS.add(key)


@functools.partial(jax.jit, static_argnames=("gs", "plan"))
def _reconstruct_jit(projections, matrices, volume, gs, plan):
    return _reconstruct_batched(projections, matrices, volume, gs, plan,
                                jnp.int32(0))


def reconstruct(projections, matrices, geom: Geometry, *,
                strategy: str = "strip2", volume=None,
                pbatch: int | None = None, plan=None, **opts):
    """Full reconstruction: stream every projection into the volume.

    ``projections`` are the *filtered* images ``(n_proj, n_v, n_u)``;
    ``matrices`` the stacked ``(n_proj, 3, 4)`` RabbitCT matrices.  The
    loop nest is batch-major (DESIGN.md §7): projections are folded into
    the volume ``pbatch`` at a time, so the volume streams through
    memory ``ceil(n_proj / pbatch)`` times instead of ``n_proj`` times.
    ``pbatch=None`` takes the resolved plan's depth
    (:data:`DEFAULT_PBATCH` when nothing tuned); ``pbatch=1`` recovers
    the per-projection nest.

    Resolution happens in ONE place — the process dispatcher
    (:mod:`repro.dispatch`, DESIGN.md §11): ``strategy="auto"`` is a
    cache hit, an in-situ first-call selection, or a logged ``strip2``
    fallback; explicit strategies validate their options strictly.  A
    pre-resolved ``plan`` (:class:`repro.dispatch.ExecutionPlan`)
    bypasses resolution entirely — ``strategy``/``opts``/``pbatch`` are
    then ignored.  For ``strip``/``strip2`` the static windows are
    validated against the host planner before any device work (see
    :func:`validate_strip_opts`).

    The jitted body is a module-level function with ``(gs, plan)``
    static, so repeated calls with one problem hit one compile-cache
    entry (``_reconstruct_jit._cache_size()``).
    """
    gs = GeomStatic.of(geom)
    if plan is None:
        from repro.dispatch import get_dispatcher

        plan = get_dispatcher().resolve(geom, strategy, opts,
                                        pbatch=pbatch)
    validate_strip_opts(geom, matrices, plan.strategy, plan.jnp_opts())
    projections = jnp.asarray(projections)
    matrices = jnp.asarray(matrices, jnp.float32)
    if volume is None:
        volume = jnp.zeros((gs.L, gs.L, gs.L), dtype=jnp.float32)
    return _reconstruct_jit(projections, matrices, volume, gs, plan)
