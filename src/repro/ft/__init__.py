"""Fault tolerance: restartable loops, preemption simulation, stragglers."""

from .manager import FaultTolerantLoop, PreemptionSimulator  # noqa: F401
