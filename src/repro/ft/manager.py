"""Fault-tolerant training loop: checkpoint/restart, stragglers, elasticity.

What "runs on 1000 nodes" means operationally, and what of it this module
implements vs. delegates:

* **Checkpoint/restart** — full: the loop persists (params, opt_state,
  step) through :class:`repro.ckpt.CheckpointManager` (async, atomic) and
  resumes *bit-exactly* (the data pipeline is stateless-addressable, so
  the step counter is the only data-side state).  Exactness is asserted
  in ``tests/test_ft.py``.
* **Preemption handling** — the loop takes an optional ``health`` callback
  per step; SIGTERM-style preemptions (simulated by
  :class:`PreemptionSimulator` in tests, wired to the cluster's
  preemption notice in production) trigger a final synchronous
  checkpoint and a clean ``Preempted`` exit that the outer restart wrapper
  (``run_with_restarts``) converts into a resume.
* **Straggler mitigation** — per-step deadline tracking: steps whose
  wall time exceeds ``straggler_factor`` x the trailing median are
  counted and surfaced; the production hook point (``on_straggler``)
  is where a cluster manager would re-shard data or evict the slow host.
  In the single-process environment we detect and log (tested with an
  artificially delayed step).
* **Elastic scaling** — restore is sharding-agnostic (see repro.ckpt),
  so a restart may present a different mesh; the loop re-places state
  against the current shardings.
"""

from __future__ import annotations

import statistics
import time
from typing import Callable

from repro.ckpt import CheckpointManager

__all__ = ["Preempted", "PreemptionSimulator", "FaultTolerantLoop",
           "run_with_restarts"]


class Preempted(Exception):
    """Raised inside the loop when the environment signals preemption."""


class PreemptionSimulator:
    """Deterministic preemption injector for tests/drills."""

    def __init__(self, at_steps: set[int]):
        self.at_steps = set(at_steps)

    def __call__(self, step: int) -> bool:
        return step in self.at_steps


class FaultTolerantLoop:
    def __init__(self, ckpt_dir: str, *, save_every: int = 50,
                 keep: int = 3, straggler_factor: float = 3.0,
                 health: Callable[[int], bool] | None = None,
                 on_straggler: Callable[[int, float], None] | None = None):
        self.mgr = CheckpointManager(ckpt_dir, keep=keep)
        self.save_every = save_every
        self.health = health or (lambda step: False)
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler or (lambda step, t: None)
        self.step_times: list[float] = []
        self.stragglers: list[int] = []

    # ------------------------------------------------------------------
    def restore_or_init(self, init_fn, shardings=None):
        """(state, start_step): latest checkpoint or fresh init."""
        latest = self.mgr.latest_step()
        if latest is None:
            return init_fn(), 0
        tree_like = jax_eval_shape_like(init_fn)
        state, step = self.mgr.restore(tree_like, shardings)
        return state, step + 1

    def run(self, state, start_step: int, n_steps: int, step_fn,
            log_every: int = 10, metrics_cb=None):
        """Run ``step_fn(state, step) -> (state, metrics)`` with FT."""
        step = start_step
        try:
            while step < n_steps:
                if self.health(step):
                    raise Preempted(f"preempted at step {step}")
                t0 = time.perf_counter()
                state, metrics = step_fn(state, step)
                dt = time.perf_counter() - t0
                self._track_straggler(step, dt)
                if metrics_cb and step % log_every == 0:
                    metrics_cb(step, metrics, dt)
                if self.save_every and step % self.save_every == 0 \
                        and step > start_step:
                    self.mgr.save_async(step, state)
                step += 1
        except Preempted:
            # Final synchronous checkpoint on the way down.
            self.mgr.wait()
            self.mgr.save_async(step - 1 if step > start_step else step,
                                state)
            self.mgr.wait()
            raise
        self.mgr.wait()
        return state, step

    # ------------------------------------------------------------------
    def _track_straggler(self, step: int, dt: float):
        if len(self.step_times) >= 5:
            med = statistics.median(self.step_times[-20:])
            if dt > self.straggler_factor * med:
                self.stragglers.append(step)
                self.on_straggler(step, dt)
        self.step_times.append(dt)


def jax_eval_shape_like(init_fn):
    """Concrete zero tree with init_fn's structure (for restore)."""
    import jax
    import jax.numpy as jnp
    sds = jax.eval_shape(init_fn)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)


def run_with_restarts(make_loop, init_fn, step_fn, n_steps: int,
                      max_restarts: int = 10, shardings=None):
    """Outer wrapper: restart-on-preemption until done.

    In production this is the per-host supervisor; here it doubles as the
    preemption drill used by ``tests/test_ft.py``.
    """
    restarts = 0
    while True:
        loop = make_loop()
        state, start = loop.restore_or_init(init_fn, shardings)
        try:
            state, step = loop.run(state, start, n_steps, step_fn)
            return state, step, restarts
        except Preempted:
            restarts += 1
            if restarts > max_restarts:
                raise
