"""Quickstart: reconstruct a phantom with every gather strategy.

Five minutes on a laptop CPU::

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.api import Geometry, filter_projections, reconstruct
from repro.core import quality_report
from repro.core.phantom import make_dataset


def main():
    geom = Geometry().scaled(32, n_proj=32)
    print(f"geometry: {geom.L}^3 voxels, {geom.n_proj} projections of "
          f"{geom.n_v}x{geom.n_u}")
    projs, mats, ref = make_dataset(geom)
    filt = filter_projections(projs, geom)

    for strategy in ("scalar", "gather", "strip", "strip2"):
        t0 = time.time()
        vol = reconstruct(filt, mats, geom, strategy=strategy)
        vol.block_until_ready()
        q = quality_report(vol, ref)
        gups = geom.L ** 3 * geom.n_proj / (time.time() - t0) / 1e9
        print(f"{strategy:8s}  psnr={q['psnr_roi_db']:6.2f} dB  "
              f"{gups:.4f} GUP/s")

    # Pallas kernel (interpret mode on CPU; TPU is the target).
    from repro.kernels.backproject_ops import pallas_backproject_one
    vol = jnp.zeros((geom.L,) * 3, jnp.float32)
    filt_np = np.asarray(filt)
    for k in range(geom.n_proj):
        vol = pallas_backproject_one(vol, filt_np[k], mats[k], geom,
                                     ty=8, chunk=32, band=16, width=128)
    q = quality_report(vol, ref)
    print(f"{'pallas':8s}  psnr={q['psnr_roi_db']:6.2f} dB  "
          f"(interpret=True)")


if __name__ == "__main__":
    main()
