"""End-to-end driver: the full RabbitCT-style benchmark run.

Synthesises a cone-beam scan of the 3-D Shepp-Logan phantom, applies FDK
preprocessing (cosine + Parker + ramp), back-projects every projection
with the production ``strip2`` strategy, and scores the reconstruction
against the analytic reference — the complete pipeline the paper's
kernel sits inside, plus a slice dump as ASCII art.

    PYTHONPATH=src python examples/reconstruct_phantom.py --L 48 --proj 96
"""

import argparse
import dataclasses
import math
import time

import numpy as np

from repro.api import Geometry, filter_projections, reconstruct
from repro.core import quality_report
from repro.core.clipping import line_clip_exact
from repro.core.phantom import make_dataset


def ascii_slice(sl, width=64):
    ramp = " .:-=+*#%@"
    sl = np.asarray(sl, np.float64)
    lo, hi = np.percentile(sl, 2), np.percentile(sl, 98)
    sl = np.clip((sl - lo) / max(hi - lo, 1e-9), 0, 1)
    step = max(1, sl.shape[0] // 32)
    rows = []
    for r in sl[::step]:
        rows.append("".join(
            ramp[int(v * (len(ramp) - 1))] for v in r[::step]))
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--L", type=int, default=48)
    ap.add_argument("--proj", type=int, default=96)
    ap.add_argument("--strategy", default="strip2")
    ap.add_argument("--pbatch", type=int, default=None,
                    help="projections folded per volume pass (DESIGN.md "
                         "§7); default: autotuned value, else 4")
    ap.add_argument("--full-sweep", action="store_true",
                    help="360-degree scan instead of the 200-degree "
                         "C-arm short scan")
    args = ap.parse_args()

    geom = Geometry().scaled(args.L, n_proj=args.proj)
    if args.full_sweep:
        geom = dataclasses.replace(geom, sweep=2 * math.pi)
    print(f"scanning: {geom.L}^3, {geom.n_proj} views, "
          f"sweep={math.degrees(geom.sweep):.0f} deg")
    t0 = time.time()
    projs, mats, ref = make_dataset(geom)
    print(f"  analytic forward projection: {time.time() - t0:.1f}s")

    t0 = time.time()
    filt = filter_projections(projs, geom)
    print(f"  FDK filter (+Parker short-scan weights): "
          f"{time.time() - t0:.1f}s")

    clip_voxels = sum(
        line_clip_exact(geom, np.asarray(m, np.float64)).voxels
        for m in mats[:: max(1, len(mats) // 8)])
    total = geom.L ** 3 * max(1, len(mats) // 8) * 8 // 8
    print(f"  clipping mask: {clip_voxels / (geom.L ** 3 * 8):.1%} of "
          "voxels contribute (sampled)")

    t0 = time.time()
    vol = reconstruct(filt, mats, geom, strategy=args.strategy,
                      pbatch=args.pbatch)
    vol.block_until_ready()
    dt = time.time() - t0
    gups = geom.L ** 3 * geom.n_proj / dt / 1e9
    print(f"  back projection [{args.strategy}]: {dt:.1f}s = "
          f"{gups:.4f} GUP/s")

    q = quality_report(vol, ref)
    print(f"  quality: PSNR(ROI) = {q['psnr_roi_db']:.2f} dB, "
          f"MSE = {q['mse_roi']:.5f}")
    print("\ncentral slice (reconstruction):")
    print(ascii_slice(np.asarray(vol)[geom.L // 2]))


if __name__ == "__main__":
    main()
