"""Train an assigned-architecture LM end to end (CPU-sized).

Fault-tolerant loop + AdamW + synthetic Markov data; a few hundred steps
drop the loss visibly.  Any ``--arch`` from the registry works (reduced
config); try a preemption drill with ``--preempt-at 40``.

    PYTHONPATH=src python examples/train_lm.py --arch chatglm3-6b \\
        --steps 200
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data.tokens import TokenDataset
from repro.ft.manager import FaultTolerantLoop, Preempted, \
    run_with_restarts
from repro.models.model import init_model
from repro.training import AdamWConfig, init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--preempt-at", type=int, default=None)
    args = ap.parse_args()

    cfg = dataclasses.replace(ARCHS[args.arch].reduced(), vocab=256)
    print(f"arch={cfg.name} ({cfg.param_count() / 1e6:.2f}M params, "
          f"family={cfg.family})")
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps, weight_decay=0.01)
    ds = TokenDataset(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    step_jit = jax.jit(make_train_step(cfg, opt_cfg, remat=False))

    def init_fn():
        params, _ = init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": init_opt_state(params, opt_cfg)}

    def step_fn(state, step):
        batch = ds.batch(jnp.int32(step))
        p, o, metrics = step_jit(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, metrics

    fired = set()

    def health(step):
        if args.preempt_at and step == args.preempt_at \
                and step not in fired:
            fired.add(step)
            print(f"  !! simulated preemption at step {step}")
            return True
        return False

    def metrics_cb(step, metrics, dt):
        print(f"step {step:4d}  loss={float(metrics['loss']):.4f}  "
              f"gnorm={float(metrics['grad_norm']):.2f}  {dt * 1e3:.0f}ms")

    def make_loop():
        return FaultTolerantLoop(args.ckpt, save_every=25, health=health)

    state, step, restarts = run_with_restarts(
        make_loop, init_fn,
        lambda s, i: _with_cb(step_fn, metrics_cb, s, i),
        args.steps)
    print(f"done at step {step} ({restarts} restarts)")


def _with_cb(step_fn, cb, state, i):
    state, metrics = step_fn(state, i)
    if i % 10 == 0:
        cb(i, metrics, 0.0)
    return state, metrics


if __name__ == "__main__":
    main()
