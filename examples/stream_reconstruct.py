"""Streamed reconstruction demo: serve scans the way a C-arm delivers them.

Simulates B concurrent acquisitions whose projections arrive as
interleaved, shuffled chunks — the engine filters each chunk on device
the moment it arrives (Parker weights selected by explicit angle index,
never by arrival position) and folds it into that scan's resident volume
``pbatch`` projections per pass.  Prints per-scan time-to-volume and the
PSNR of every result against the analytic phantom.

    PYTHONPATH=src python examples/stream_reconstruct.py --L 32 --proj 32 \
        --scans 3 --slots 2 --chunk 4 --shuffle
"""

import argparse
import time

import numpy as np

from repro.api import Geometry, ProjectionChunk, ReconstructionEngine
from repro.core import quality_report
from repro.core.phantom import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--L", type=int, default=32)
    ap.add_argument("--proj", type=int, default=32)
    ap.add_argument("--scans", type=int, default=3)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--pbatch", type=int, default=4)
    ap.add_argument("--strategy", default="strip2")
    ap.add_argument("--shuffle", action="store_true",
                    help="deliver chunks in shuffled angle order (the "
                         "angle_indices contract makes this safe)")
    args = ap.parse_args()

    geom = Geometry().scaled(args.L, n_proj=args.proj)
    print(f"geometry: {geom.L}^3, {geom.n_proj} views; "
          f"{args.scans} scan(s) over {args.slots} slot(s)")
    projs, mats, ref = make_dataset(geom)
    projs = np.asarray(projs, np.float32)

    order = np.arange(geom.n_proj)
    if args.shuffle:
        order = np.random.default_rng(0).permutation(order)
    chunks = [order[i:i + args.chunk]
              for i in range(0, geom.n_proj, args.chunk)]

    eng = ReconstructionEngine(geom, n_slots=args.slots,
                               strategy=args.strategy, pbatch=args.pbatch)
    t0 = time.time()
    sids = [eng.begin_scan(n_proj=geom.n_proj) for _ in range(args.scans)]
    started = {sid: None for sid in sids}
    finished = {}
    # Round-robin arrival across scans, chunked, possibly shuffled.
    for chunk in chunks:
        for sid in sids:
            if started[sid] is None:
                started[sid] = time.time()
            eng.submit(sid, ProjectionChunk(projs[chunk], mats[chunk],
                                            chunk))
            if eng.scans[sid].done and sid not in finished:
                finished[sid] = time.time()
    eng.drain()
    for sid in sids:
        finished.setdefault(sid, time.time())
    print(f"streamed {args.scans * geom.n_proj} projections in "
          f"{time.time() - t0:.2f}s "
          f"({args.scans * geom.n_proj / (time.time() - t0):.1f} proj/s); "
          f"fold ticks: {eng.stats['fold_ticks']}")

    for sid in sids:
        vol = np.asarray(eng.result(sid))
        q = quality_report(vol, ref)
        print(f"  scan {sid}: time-to-volume "
              f"{finished[sid] - started[sid]:.2f}s, "
              f"PSNR(ROI) = {q['psnr_roi_db']:.2f} dB")


if __name__ == "__main__":
    main()
