"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-vl-2b
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models.model import init_model
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, n_slots=args.slots, max_len=128)
    rng = np.random.default_rng(0)

    reqs = []
    for i in range(args.requests):
        r = Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4 + 2 * i),
                    max_tokens=args.max_tokens,
                    temperature=0.7 if i % 2 else 0.0)
        reqs.append(r)
        engine.submit(r)
    t0 = time.time()
    ticks = engine.run_until_done()
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in reqs)
    print(f"{args.requests} requests on {args.slots} slots: "
          f"{ticks} ticks, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s)")
    for r in reqs:
        print(f"  req{r.rid} prompt_len={len(r.prompt)} "
              f"T={r.temperature} out={r.out_tokens}")


if __name__ == "__main__":
    main()
